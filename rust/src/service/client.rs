//! The service client: accelerator-host side of tf.data service.
//!
//! [`ServiceClient::distribute`] is the Rust analogue of Fig. 4's
//! `ds.distribute(...)`: it optimizes and registers the pipeline with the
//! dispatcher, joins (or creates) a job, discovers workers via heartbeats,
//! and returns an iterator that fetches preprocessed batches over RPC.
//!
//! * Independent mode: one fetcher thread per worker pulls into a bounded
//!   client-side buffer ("clients can request data from multiple workers
//!   in parallel", §3.1).
//! * Coordinated mode: the client walks rounds 0, 1, 2, …, asking the
//!   worker that owns each round for its `consumer_index` slot (§3.6).

use super::proto::*;
use super::worker::inflate;
use super::{ServiceError, ServiceResult};
use crate::data::exec::ElemIter;
use crate::data::graph::GraphDef;
use crate::data::optimize::{optimize, OptimizeOptions};
use crate::data::{DataResult, Element};
use crate::metrics::Registry;
use crate::rpc::{call_typed, Pool};
use crate::util::chan;
use crate::wire::Decode;
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Per-job client configuration (the `distribute(...)` kwargs).
#[derive(Debug, Clone)]
pub struct ServiceClientConfig {
    pub sharding: ShardingPolicy,
    pub mode: ProcessingMode,
    /// Shared job name; empty = anonymous job (subject to `sharing`).
    pub job_name: String,
    /// Cross-job ephemeral sharing (§3.5). `Auto`: an anonymous
    /// independent-mode job attaches to a live job running the exact same
    /// pipeline (by structural fingerprint) instead of re-producing it —
    /// note this trades the visitation guarantee for cost: a client
    /// attaching mid-stream starts at the oldest *retained* window
    /// element (relaxed visitation), so opt in only when that is
    /// acceptable (e.g. hyperparameter sweeps). `Off` (default): always
    /// create a dedicated production with the full guarantee.
    pub sharing: SharingMode,
    /// Coordinated mode: total consumers and this client's slot.
    pub num_consumers: u32,
    pub consumer_index: u32,
    pub compression: CompressionMode,
    /// Client-side buffer depth (elements).
    pub buffer_size: usize,
    /// Max parallel fetchers (one per worker up to this cap).
    pub max_fetchers: usize,
    pub request_timeout: Duration,
    /// How often to refresh the worker list from the dispatcher.
    pub heartbeat_interval: Duration,
    /// Legacy-plane selector, consulted only when the session plane is
    /// not in use — `stream_sessions` is false, or the worker rejected
    /// the handshake: true = batched `GetElements`, false = the
    /// one-element-per-RPC `GetElement` path. To actually force
    /// one-element-per-RPC, set `stream_sessions: false` as well.
    /// Independent mode only; coordinated reads always move one round
    /// slot per call.
    pub batching: bool,
    /// Max elements per batched response; 0 = worker default. With
    /// adaptive batching this is the AIMD starting point, not a constant.
    pub batch_max_elements: u32,
    /// Per-response byte budget (flow control: bounds per-worker client
    /// memory to ~2x this with the request pipeline); 0 = worker default.
    /// With adaptive batching this is the AIMD starting point.
    pub batch_max_bytes: u64,
    /// Worker-side long-poll window when its buffer is empty; 0 = worker
    /// default.
    pub batch_poll_ms: u32,
    /// Use the versioned stream-session data plane (`OpenStream`/`Fetch`,
    /// the default): capability negotiation, chunked transfer of
    /// oversized elements, and adaptive batching. The client downgrades
    /// automatically to the legacy RPCs against an old worker that does
    /// not implement the handshake.
    pub stream_sessions: bool,
    /// Run an AIMD loop on `batch_max_elements`/`batch_max_bytes` per
    /// worker, driven by the backpressure hints in `Fetch` responses,
    /// instead of using the static config values. Requires
    /// `stream_sessions` and the worker granting
    /// [`proto::stream_caps::ADAPTIVE_BATCHING`].
    pub adaptive_batching: bool,
    /// Largest response frame this client accepts (advertised in the
    /// handshake; elements over the negotiated value arrive as
    /// continuation frames). 0 = the transport cap.
    pub max_frame_len: u64,
    /// Coordinated mode: how many rounds the fetch engine may run ahead
    /// of the trainer (§3.6 round prefetch). 2 = double buffering — the
    /// `Fetch` for round `r+1` is in flight (or done) while the trainer
    /// consumes round `r`, so the materialize+RPC+decode round-trip
    /// leaves the step critical path. 0 = today's lock-step behavior
    /// (fetch a round only when the trainer blocks on it). Requires the
    /// stream-session plane and workers granting
    /// [`proto::stream_caps::ROUND_PREFETCH`]; the engine downgrades to
    /// lock-step automatically when any owner does not.
    pub round_prefetch_depth: u32,
    /// Coordinated mode: fetch prefetched rounds **concurrently across
    /// distinct owner workers** (at most one in-flight round per owner,
    /// up to `round_prefetch_depth` rounds ahead of demand) instead of
    /// walking the prefetch window with one serial fetch at a time. On a
    /// k-worker topology the round cadence then approaches `fetch/k`
    /// because transfers from different owners overlap. Ignored in
    /// lock-step mode (depth 0, `stream_sessions: false`, or a peer
    /// without `ROUND_PREFETCH`). Default on; turning it off restores
    /// the single-threaded pipelined engine.
    pub concurrent_round_fetch: bool,
}

impl Default for ServiceClientConfig {
    fn default() -> Self {
        ServiceClientConfig {
            sharding: ShardingPolicy::Off,
            mode: ProcessingMode::Independent,
            job_name: String::new(),
            sharing: SharingMode::Off,
            num_consumers: 0,
            consumer_index: 0,
            compression: CompressionMode::None,
            buffer_size: 16,
            max_fetchers: 8,
            request_timeout: Duration::from_secs(10),
            heartbeat_interval: Duration::from_millis(100),
            batching: true,
            batch_max_elements: 0,
            batch_max_bytes: 1 << 20,
            batch_poll_ms: 0,
            stream_sessions: true,
            adaptive_batching: true,
            max_frame_len: 0,
            round_prefetch_depth: 2,
            concurrent_round_fetch: true,
        }
    }
}

// AIMD bounds for adaptive batching: additive increase while responses
// come back full and the worker reports more data ready, multiplicative
// decrease when a long-poll expires empty (production is the bottleneck,
// so small requests keep latency low).
const AIMD_MIN_ELEMENTS: u32 = 16;
const AIMD_MAX_ELEMENTS: u32 = 1024;
const AIMD_ELEMENTS_STEP: u32 = 32;
const AIMD_MIN_BYTES: u64 = 64 << 10;
const AIMD_MAX_BYTES: u64 = 8 << 20;
const AIMD_BYTES_STEP: u64 = 256 << 10;

/// Handle for talking to one tf.data service deployment.
pub struct ServiceClient {
    dispatcher_addr: String,
    pool: Arc<Pool>,
    metrics: Registry,
    /// When set, every registration resolves referenced UDF names against
    /// this registry and ships their body digests, so the one-call
    /// `distribute` flow gets fingerprint protection against same-name /
    /// different-body UDFs without the explicit two-step API.
    udfs: Option<crate::data::udf::UdfRegistry>,
}

impl ServiceClient {
    pub fn new(dispatcher_addr: &str) -> ServiceClient {
        ServiceClient {
            dispatcher_addr: dispatcher_addr.to_string(),
            pool: Arc::new(Pool::with_defaults()),
            metrics: Registry::new(),
            udfs: None,
        }
    }

    /// A client that mixes UDF body digests from `udfs` into every
    /// pipeline fingerprint it registers (see `RegisterDatasetReq`).
    pub fn with_udfs(dispatcher_addr: &str, udfs: crate::data::udf::UdfRegistry) -> ServiceClient {
        ServiceClient { udfs: Some(udfs), ..ServiceClient::new(dispatcher_addr) }
    }

    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Register `graph` (after static optimization, §3.2) and return the
    /// dataset id (= canonical pipeline fingerprint). Uses the client's
    /// UDF registry (if constructed via [`ServiceClient::with_udfs`]) for
    /// body digests.
    pub fn register_dataset(&self, graph: &GraphDef) -> ServiceResult<u64> {
        self.register_dataset_with_udfs(graph, self.udfs.as_ref())
    }

    /// [`ServiceClient::register_dataset`] carrying body digests for the
    /// UDFs the graph references, resolved from `udfs`: two clients whose
    /// registries hold different implementations under one name then get
    /// different fingerprints and never share ephemeral data.
    pub fn register_dataset_with_udfs(
        &self,
        graph: &GraphDef,
        udfs: Option<&crate::data::udf::UdfRegistry>,
    ) -> ServiceResult<u64> {
        let optimized = optimize(graph, &OptimizeOptions::default());
        let mut udf_digests = Vec::new();
        if let Some(reg) = udfs {
            for node in &optimized.nodes {
                use crate::data::graph::Node;
                let name = match node {
                    Node::Map { udf, .. } | Node::Filter { udf } => udf,
                    _ => continue,
                };
                if let Some(digest) = reg.digest(name) {
                    udf_digests.push(UdfDigest { name: name.clone(), digest });
                }
            }
        }
        let resp: RegisterDatasetResp = call_typed(
            &self.pool,
            &self.dispatcher_addr,
            dispatcher_methods::REGISTER_DATASET,
            &RegisterDatasetReq { graph: optimized, udf_digests },
            Duration::from_secs(10),
        )?;
        Ok(resp.dataset_id)
    }

    /// The full `distribute` flow: register + join job + start fetching.
    pub fn distribute(&self, graph: &GraphDef, cfg: ServiceClientConfig) -> ServiceResult<DistributedIter> {
        let dataset_id = self.register_dataset(graph)?;
        self.distribute_dataset(dataset_id, cfg)
    }

    /// Join (or create) a job over an already-registered dataset. An
    /// [`OVERLOADED_PREFIX`](super::OVERLOADED_PREFIX) shed from the
    /// dispatcher's admission control is retried here with jittered
    /// backoff around the server's `retry after N ms` hint
    /// (`client/admission_retries`) — the shed is flow control, not
    /// failure — up to a bounded attempt budget before surfacing.
    pub fn distribute_dataset(
        &self,
        dataset_id: u64,
        cfg: ServiceClientConfig,
    ) -> ServiceResult<DistributedIter> {
        let req = GetOrCreateJobReq {
            dataset_id,
            job_name: cfg.job_name.clone(),
            sharding: cfg.sharding,
            mode: cfg.mode,
            num_consumers: cfg.num_consumers,
            sharing: cfg.sharing,
        };
        const ADMISSION_ATTEMPTS: u32 = 32;
        let mut jitter = crate::util::rng::Rng::new(
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.subsec_nanos() as u64 ^ d.as_secs())
                .unwrap_or(0x5eed)
                ^ dataset_id,
        );
        let mut attempt = 0u32;
        let job: GetOrCreateJobResp = loop {
            match call_typed(
                &self.pool,
                &self.dispatcher_addr,
                dispatcher_methods::GET_OR_CREATE_JOB,
                &req,
                Duration::from_secs(10),
            ) {
                Ok(resp) => break resp,
                Err(crate::rpc::RpcError::Remote(msg))
                    if msg.contains(super::OVERLOADED_PREFIX) && attempt + 1 < ADMISSION_ATTEMPTS =>
                {
                    attempt += 1;
                    self.metrics.counter("client/admission_retries").inc();
                    // Hinted delay ±50% jitter: a storm of shed clients
                    // must not re-arrive in lockstep and be shed again.
                    let hint = parse_retry_hint(&msg).unwrap_or(25).max(1);
                    let wait = jitter.range_u64(hint / 2, hint + hint / 2);
                    std::thread::sleep(Duration::from_millis(wait));
                }
                Err(e) => return Err(e.into()),
            }
        };
        // Anonymous attaches are fingerprint (§3.5) sharing; named joins
        // are explicit grouping — mirror the dispatcher's counter split.
        if job.attached && cfg.job_name.is_empty() {
            self.metrics.counter("client/shared_attaches").inc();
        }
        // Snapshot serve: the job streams a committed epoch from the
        // store (fingerprint-keyed reuse) instead of producing.
        if job.snapshot {
            self.metrics.counter("client/snapshot_attaches").inc();
        }
        DistributedIter::start(
            self.dispatcher_addr.clone(),
            self.pool.clone(),
            job.job_id,
            job.client_id,
            job.attached,
            job.snapshot,
            cfg,
            self.metrics.clone(),
        )
    }
}

/// Iterator over a distributed job's elements.
pub struct DistributedIter {
    mode: ProcessingMode,
    // Independent mode:
    rx: Option<chan::Receiver<ServiceResult<Element>>>,
    /// Sender handle used only to force-close the buffer on release, so
    /// fetchers blocked on a full buffer unwedge when the consumer stops
    /// mid-stream instead of leaking.
    tx_close: Option<chan::Sender<ServiceResult<Element>>>,
    // Coordinated mode:
    coord: Option<CoordConsumer>,
    // Common:
    job_id: u64,
    client_id: u64,
    /// Whether this client attached to an already-live job (§3.5 sharing)
    /// instead of creating a new production.
    attached: bool,
    /// Whether the job serves a committed fingerprint-keyed snapshot
    /// from the store instead of running the pipeline.
    snapshot: bool,
    dispatcher_addr: String,
    pool: Arc<Pool>,
    stop: Arc<AtomicBool>,
    /// Closing this wakes every fetcher blocked in a backoff wait
    /// (event-driven wakeup — a release never waits out a sleep).
    halt_tx: chan::Sender<()>,
    released: bool,
    /// Input-stall accounting shared with the heartbeat thread (the
    /// autoscaler's client-starvation signal).
    stall: Arc<StallStats>,
}

/// Shared input-stall accounting between the trainer-facing iterator and
/// the heartbeat thread: `next()` records each fetch and whether the
/// element was already buffered; the heartbeat thread drains the window
/// and reports the stall fraction in thousandths (the dispatcher
/// aggregates these into the autoscaler's client-starvation signal,
/// §3.1 right-sizing).
#[derive(Default)]
struct StallStats {
    fetches: AtomicU64,
    stalls: AtomicU64,
}

impl StallStats {
    fn record(&self, stalled: bool) {
        self.fetches.fetch_add(1, Ordering::Relaxed);
        if stalled {
            self.stalls.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Drain the window: stall fraction in thousandths [0, 1000]. 0 when
    /// no fetches happened in the window — a busy trainer is not a
    /// starved one.
    fn take_fraction_milli(&self) -> u32 {
        let f = self.fetches.swap(0, Ordering::Relaxed);
        let s = self.stalls.swap(0, Ordering::Relaxed);
        if f == 0 {
            0
        } else {
            (s.min(f) * 1000 / f) as u32
        }
    }
}

/// State shared between the coordinated fetch engine thread, the
/// heartbeat thread, and the consuming iterator.
struct CoordShared {
    /// Round routing, refreshed by the heartbeat thread: residue-indexed
    /// lease holders (preferred) plus the plain worker list (fallback
    /// against a pre-lease dispatcher).
    owners: Mutex<CoordOwners>,
    owners_changed: Condvar,
    /// Rounds the trainer has demanded so far (bumped by `next()`): the
    /// engine's pacing gate. In lock-step mode the engine fetches round
    /// `r` only once `demand > r`; with prefetch it runs up to `depth`
    /// rounds ahead. The condvar also carries fetch-lane completion
    /// wakeups ([`run_concurrent`]'s event-driven wait).
    demand: Mutex<u64>,
    demand_changed: Condvar,
    /// First round this consumer's slot no longer exists at — the
    /// shrink barrier of a membership epoch that dropped this slot,
    /// learned from the heartbeat. `u64::MAX` while the slot is live.
    /// The engine drains up to it, then delivers a clean end of
    /// sequence instead of waiting on rounds it holds no slot in.
    eos_at: AtomicU64,
}

#[derive(Default)]
struct CoordOwners {
    worker_addrs: Vec<String>,
    round_owner_addrs: Vec<String>,
    /// Job-wide materialization floor from the last heartbeat: a fresh
    /// consumer fast-forwards its round walk here (rounds below it were
    /// consumed by every live consumer and can no longer be fetched).
    round_floor: u64,
}

/// Consumer half of the coordinated round pipeline: `next()` announces
/// demand, then blocks on the bounded round channel the engine fills.
struct CoordConsumer {
    rx: chan::Receiver<crate::data::DataResult<Option<Element>>>,
    /// Engine-side sender clone, closed on release to unwedge a blocked
    /// engine.
    tx_close: chan::Sender<crate::data::DataResult<Option<Element>>>,
    shared: Arc<CoordShared>,
    /// Rounds fully delivered to the trainer; reported to the dispatcher
    /// as `next_round` (the round-lease reassignment floor).
    delivered: Arc<AtomicU64>,
    timeout: Duration,
    /// End-of-sequence delivered: further `next()` calls return None
    /// immediately instead of waiting on a finished engine.
    finished: bool,
}

impl CoordConsumer {
    /// Tell the engine the trainer now wants the round after the last
    /// delivered one (wakes a lock-step engine; a prefetching engine is
    /// already ahead).
    fn announce_demand(&self) {
        let want = self.delivered.load(Ordering::SeqCst) + 1;
        let mut d = self.shared.demand.lock().unwrap();
        if *d < want {
            *d = want;
            self.shared.demand_changed.notify_all();
        }
    }
}

struct FetchShared {
    job_id: u64,
    client_id: u64,
    compression: CompressionMode,
    timeout: Duration,
    pool: Arc<Pool>,
    tx: chan::Sender<ServiceResult<Element>>,
    stop: Arc<AtomicBool>,
    /// Backoff waits block here instead of sleeping: the channel never
    /// carries items, so `recv_timeout` is a pure interruptible timer
    /// that returns `Err(Closed)` the instant the iterator releases.
    halt: chan::Receiver<()>,
    metrics: Registry,
    /// Workers that reported end_of_sequence.
    finished_workers: Mutex<HashSet<String>>,
    active_fetchers: AtomicU64,
    // Batched-path knobs (see ServiceClientConfig).
    batching: bool,
    batch_max_elements: u32,
    batch_max_bytes: u64,
    batch_poll_ms: u32,
    // Stream-session knobs (see ServiceClientConfig).
    stream_sessions: bool,
    adaptive_batching: bool,
    max_frame_len: u64,
}

impl FetchShared {
    /// Interruptible backoff: waits `dur` unless the iterator released
    /// first. Returns false when the fetcher should stop.
    fn backoff(&self, dur: Duration) -> bool {
        match self.halt.recv_timeout(dur) {
            Err(chan::Closed) => false,
            Ok(_) => !self.stop.load(Ordering::SeqCst),
        }
    }
}

impl DistributedIter {
    fn start(
        dispatcher_addr: String,
        pool: Arc<Pool>,
        job_id: u64,
        client_id: u64,
        attached: bool,
        snapshot: bool,
        cfg: ServiceClientConfig,
        metrics: Registry,
    ) -> ServiceResult<DistributedIter> {
        let stop = Arc::new(AtomicBool::new(false));
        let (halt_tx, halt_rx) = chan::bounded::<()>(1);
        let stall = Arc::new(StallStats::default());
        match cfg.mode {
            ProcessingMode::Coordinated => {
                let shared = Arc::new(CoordShared {
                    owners: Mutex::new(CoordOwners::default()),
                    owners_changed: Condvar::new(),
                    demand: Mutex::new(0),
                    demand_changed: Condvar::new(),
                    eos_at: AtomicU64::new(u64::MAX),
                });
                // Round progress starts at the "unknown" sentinel: until
                // this consumer learns the job floor, its heartbeats must
                // not report `next_round: 0` — that would drag the
                // job-wide floor (the min over consumers) to 0 and defeat
                // the fast-forward below.
                let delivered = Arc::new(AtomicU64::new(u64::MAX));
                // Heartbeat thread: refresh worker + round-owner routing
                // (lease reassignments propagate here) and report this
                // consumer's round progress for the reassignment floor.
                {
                    let shared = shared.clone();
                    let delivered = delivered.clone();
                    let pool2 = pool.clone();
                    let da = dispatcher_addr.clone();
                    let stop2 = stop.clone();
                    let halt = halt_rx.clone();
                    let hb = cfg.heartbeat_interval;
                    let ci = cfg.consumer_index;
                    let stall2 = stall.clone();
                    std::thread::Builder::new()
                        .name("svc-client-hb".into())
                        .spawn(move || {
                            while !stop2.load(Ordering::SeqCst) {
                                let next_round = delivered.load(Ordering::SeqCst);
                                let stall_milli = stall2.take_fraction_milli();
                                if let Ok(resp) = heartbeat(
                                    &pool2, &da, job_id, client_id, ci, next_round, stall_milli,
                                ) {
                                    let mut o = shared.owners.lock().unwrap();
                                    o.worker_addrs = resp.worker_addrs;
                                    o.round_owner_addrs = resp.round_owner_addrs;
                                    o.round_floor = resp.round_floor;
                                    drop(o);
                                    shared.owners_changed.notify_all();
                                    // Membership shrink (§3.6 elasticity):
                                    // the newest epoch no longer includes
                                    // this slot — drain to the barrier and
                                    // end cleanly. (A pre-epoch dispatcher
                                    // reports num_consumers 0: ignore.)
                                    if resp.num_consumers > 0
                                        && ci >= resp.num_consumers
                                        && shared.eos_at.load(Ordering::SeqCst)
                                            > resp.width_barrier_round
                                    {
                                        shared
                                            .eos_at
                                            .store(resp.width_barrier_round, Ordering::SeqCst);
                                        // Wake engines parked on either gate.
                                        let _g = shared.demand.lock().unwrap();
                                        drop(_g);
                                        shared.demand_changed.notify_all();
                                        shared.owners_changed.notify_all();
                                    }
                                }
                                if halt.recv_timeout(hb).is_err() {
                                    break;
                                }
                            }
                        })
                        .ok();
                }
                // Wait for at least one worker to appear (condvar-driven).
                {
                    let deadline = Instant::now() + Duration::from_secs(10);
                    let mut o = shared.owners.lock().unwrap();
                    while o.worker_addrs.is_empty() && o.round_owner_addrs.is_empty() {
                        let now = Instant::now();
                        if now >= deadline {
                            return Err(ServiceError::Other(
                                "no workers for coordinated job".into(),
                            ));
                        }
                        let (next, _) = shared
                            .owners_changed
                            .wait_timeout(o, deadline - now)
                            .unwrap();
                        o = next;
                    }
                }
                // Fast-forward a fresh consumer to the job's
                // materialization floor (client restart / mid-epoch slot
                // takeover): rounds below it were consumed by every live
                // consumer, so asking their owners again would only earn
                // "round already consumed" errors.
                let start_round = shared.owners.lock().unwrap().round_floor;
                delivered.store(start_round, Ordering::SeqCst);
                // Round pipeline: the engine fetches rounds (up to
                // `round_prefetch_depth` ahead of trainer demand) into a
                // bounded channel the iterator drains.
                let depth = cfg.round_prefetch_depth as usize;
                let (btx, brx) = chan::bounded::<crate::data::DataResult<Option<Element>>>(
                    depth.max(1),
                );
                let tx_close = btx.clone();
                let lockstep = !cfg.stream_sessions || cfg.round_prefetch_depth == 0;
                let concurrent = cfg.concurrent_round_fetch && !lockstep;
                let engine = Arc::new(CoordEngine {
                    pool: pool.clone(),
                    job_id,
                    client_id,
                    consumer_index: cfg.consumer_index,
                    compression: cfg.compression,
                    timeout: cfg.request_timeout,
                    stream_sessions: cfg.stream_sessions,
                    max_frame_len: cfg.max_frame_len,
                    prefetch_depth: cfg.round_prefetch_depth as u64,
                    lockstep: AtomicBool::new(lockstep),
                    shared: shared.clone(),
                    delivered: delivered.clone(),
                    stop: stop.clone(),
                    halt: halt_rx.clone(),
                    metrics: metrics.clone(),
                });
                std::thread::Builder::new()
                    .name(format!("svc-coord-eng-{job_id}"))
                    .spawn(move || {
                        if concurrent {
                            run_concurrent(&engine, start_round, btx);
                        } else {
                            run_sequential(&engine, start_round, btx);
                        }
                    })
                    .ok();
                Ok(DistributedIter {
                    mode: cfg.mode,
                    rx: None,
                    tx_close: None,
                    coord: Some(CoordConsumer {
                        rx: brx,
                        tx_close,
                        shared,
                        delivered,
                        timeout: cfg.request_timeout,
                        finished: false,
                    }),
                    job_id,
                    client_id,
                    attached,
                    snapshot,
                    dispatcher_addr,
                    pool,
                    stop,
                    halt_tx,
                    released: false,
                    stall,
                })
            }
            ProcessingMode::Independent => {
                let (tx, rx) = chan::bounded::<ServiceResult<Element>>(cfg.buffer_size);
                let tx_close = tx.clone();
                let shared = Arc::new(FetchShared {
                    job_id,
                    client_id,
                    compression: cfg.compression,
                    timeout: cfg.request_timeout,
                    pool: pool.clone(),
                    tx,
                    stop: stop.clone(),
                    halt: halt_rx,
                    metrics: metrics.clone(),
                    finished_workers: Mutex::new(HashSet::new()),
                    active_fetchers: AtomicU64::new(0),
                    batching: cfg.batching,
                    batch_max_elements: cfg.batch_max_elements,
                    batch_max_bytes: cfg.batch_max_bytes,
                    batch_poll_ms: cfg.batch_poll_ms,
                    stream_sessions: cfg.stream_sessions,
                    adaptive_batching: cfg.adaptive_batching,
                    max_frame_len: cfg.max_frame_len,
                });
                // Supervisor: heartbeat the dispatcher, spawn a fetcher per
                // (newly discovered) worker, close the channel when done.
                let da = dispatcher_addr.clone();
                let max_fetchers = cfg.max_fetchers;
                let hb = cfg.heartbeat_interval;
                let stall2 = stall.clone();
                std::thread::Builder::new()
                    .name("svc-client-supervisor".into())
                    .spawn(move || {
                        let mut known: HashSet<String> = HashSet::new();
                        loop {
                            if shared.stop.load(Ordering::SeqCst) {
                                break;
                            }
                            let stall_milli = stall2.take_fraction_milli();
                            match heartbeat(&shared.pool, &da, job_id, client_id, 0, 0, stall_milli)
                            {
                                Ok(resp) => {
                                    for addr in resp.worker_addrs {
                                        if known.len() >= max_fetchers {
                                            break;
                                        }
                                        if known.insert(addr.clone()) {
                                            if shared.stream_sessions {
                                                spawn_session_fetcher(shared.clone(), addr);
                                            } else if shared.batching {
                                                spawn_batched_fetcher(shared.clone(), addr);
                                            } else {
                                                spawn_fetcher(shared.clone(), addr);
                                            }
                                        }
                                    }
                                    let all_finished = !known.is_empty()
                                        && shared.finished_workers.lock().unwrap().len() == known.len();
                                    if resp.job_finished || all_finished {
                                        break;
                                    }
                                }
                                Err(_) => {
                                    // Dispatcher down: keep fetching from
                                    // known workers (§3.4).
                                }
                            }
                            if shared.halt.recv_timeout(hb).is_err() {
                                break;
                            }
                        }
                        // Wait for fetchers to drain, then close.
                        while shared.active_fetchers.load(Ordering::SeqCst) > 0 {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        shared.tx.close();
                    })
                    .ok();
                Ok(DistributedIter {
                    mode: cfg.mode,
                    rx: Some(rx),
                    tx_close: Some(tx_close),
                    coord: None,
                    job_id,
                    client_id,
                    attached,
                    snapshot,
                    dispatcher_addr,
                    pool,
                    stop,
                    halt_tx,
                    released: false,
                    stall,
                })
            }
        }
    }

    pub fn job_id(&self) -> u64 {
        self.job_id
    }

    /// This client's consumer identity within the job (the cursor key on
    /// the worker's multi-consumer cache).
    pub fn client_id(&self) -> u64 {
        self.client_id
    }

    /// True when `distribute` attached to an already-live job — via the
    /// §3.5 fingerprint match (anonymous + `sharing: auto`) or an
    /// explicit job-name join — instead of starting a new production.
    pub fn attached(&self) -> bool {
        self.attached
    }

    /// True when the job serves a committed fingerprint-keyed snapshot:
    /// workers stream the stored epoch (paying storage read costs)
    /// instead of re-running the pipeline.
    pub fn snapshot(&self) -> bool {
        self.snapshot
    }

    /// Tell the dispatcher this client is done (job GC'd when the last
    /// client releases).
    pub fn release(&mut self) {
        if self.released {
            return;
        }
        self.released = true;
        self.stop.store(true, Ordering::SeqCst);
        // Wake every fetcher parked in a backoff wait (event-driven: a
        // release never waits out a sleep).
        self.halt_tx.close();
        // Unwedge fetchers blocked on a full buffer: a consumer stopping
        // mid-stream must not leak fetcher threads.
        if let Some(tx) = &self.tx_close {
            tx.close();
        }
        if let Some(coord) = &self.coord {
            coord.tx_close.close();
            // Wake engines parked on the demand gate. Bracketing the
            // notify with the demand lock orders it after an engine
            // that observed `stop` unset and is about to wait, so
            // teardown never rides out the watchdog timeout.
            drop(coord.shared.demand.lock().unwrap());
            coord.shared.demand_changed.notify_all();
            coord.shared.owners_changed.notify_all();
        }
        let _: Result<ReleaseJobResp, _> = call_typed(
            &self.pool,
            &self.dispatcher_addr,
            dispatcher_methods::RELEASE_JOB,
            &ReleaseJobReq { job_id: self.job_id, client_id: self.client_id },
            Duration::from_secs(5),
        );
    }

    /// Stop this iterator's threads and channels **without** telling
    /// the dispatcher (no `ReleaseJob`): the consumer simply goes
    /// silent, exactly like a crashed trainer process. The fault
    /// harness uses this to exercise slot replacement — the dispatcher
    /// must notice the silence via lease expiry, and a later client on
    /// the same consumer slot must be able to take over.
    pub fn abandon(&mut self) {
        if self.released {
            return;
        }
        self.released = true;
        self.stop.store(true, Ordering::SeqCst);
        self.halt_tx.close();
        if let Some(tx) = &self.tx_close {
            tx.close();
        }
        if let Some(coord) = &self.coord {
            coord.tx_close.close();
            drop(coord.shared.demand.lock().unwrap());
            coord.shared.demand_changed.notify_all();
            coord.shared.owners_changed.notify_all();
        }
    }
}

impl Drop for DistributedIter {
    fn drop(&mut self) {
        self.release();
    }
}

fn heartbeat(
    pool: &Pool,
    dispatcher: &str,
    job_id: u64,
    client_id: u64,
    consumer_index: u32,
    next_round: u64,
    stall_fraction_milli: u32,
) -> ServiceResult<ClientHeartbeatResp> {
    Ok(call_typed(
        pool,
        dispatcher,
        dispatcher_methods::CLIENT_HEARTBEAT,
        &ClientHeartbeatReq { job_id, client_id, next_round, consumer_index, stall_fraction_milli },
        Duration::from_secs(5),
    )?)
}

fn spawn_fetcher(shared: Arc<FetchShared>, addr: String) {
    shared.active_fetchers.fetch_add(1, Ordering::SeqCst);
    let outer = shared.clone();
    let spawned = std::thread::Builder::new()
        .name(format!("svc-fetch-{addr}"))
        .spawn(move || {
            single_fetch_loop(&shared, &addr);
            shared.active_fetchers.fetch_sub(1, Ordering::SeqCst);
        });
    if spawned.is_err() {
        // Spawn failure must not wedge the supervisor's drain wait.
        outer.active_fetchers.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Legacy one-element-per-RPC fetch loop (`batching: false`, or the
/// downgrade path against a pre-session worker).
fn single_fetch_loop(shared: &Arc<FetchShared>, addr: &str) {
    // Transient-failure budget: the worker may not have received
    // the task yet (it arrives on its next heartbeat), or may be
    // restarting. Only after sustained failure do we give up.
    let mut consecutive_errors = 0u32;
    const MAX_CONSECUTIVE_ERRORS: u32 = 25;
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        let req = GetElementReq {
            job_id: shared.job_id,
            client_id: shared.client_id,
            consumer_index: None,
            round: None,
            compression: shared.compression,
        };
        let resp: Result<GetElementResp, _> =
            call_typed(&shared.pool, addr, worker_methods::GET_ELEMENT, &req, shared.timeout);
        shared.metrics.counter("client/rpcs").inc();
        match resp {
            Ok(r) => {
                consecutive_errors = 0;
                if r.end_of_sequence {
                    shared.finished_workers.lock().unwrap().insert(addr.to_string());
                    break;
                }
                match r.element {
                    Some(bytes) => {
                        let decoded = decode_element(&bytes, r.compressed);
                        shared.metrics.counter("client/elements_fetched").inc();
                        shared.metrics.counter("client/bytes_fetched").add(bytes.len() as u64);
                        if shared.tx.send(decoded).is_err() {
                            break;
                        }
                    }
                    None => {
                        // Worker had nothing ready after its long-poll:
                        // retry immediately — the next RPC blocks
                        // worker-side on its condvar, so this loop is
                        // paced by real events, not a sleep.
                    }
                }
            }
            Err(crate::rpc::RpcError::Remote(msg))
                if msg.contains(super::ELEMENT_TOO_LARGE_PREFIX) =>
            {
                // Terminal, not transient: the stream contains an element
                // the single-element frame cannot carry. Surface the
                // explicit error instead of burning the retry budget.
                let _ = shared.tx.send(Err(ServiceError::Other(msg)));
                shared.finished_workers.lock().unwrap().insert(addr.to_string());
                break;
            }
            Err(e) => {
                // Transient: the task may not have reached the
                // worker yet, or the worker is restarting. Retry
                // with backoff; give up only after sustained
                // failure (preemption). The supervisor keeps the
                // job going on surviving workers.
                shared.metrics.counter("client/fetch_errors").inc();
                let _ = e;
                consecutive_errors += 1;
                if consecutive_errors >= MAX_CONSECUTIVE_ERRORS {
                    shared.finished_workers.lock().unwrap().insert(addr.to_string());
                    break;
                }
                if !shared.backoff(Duration::from_millis(20)) {
                    break;
                }
            }
        }
    }
}

/// Batched streaming fetcher: one pipeline per worker. A dedicated
/// requester thread keeps the next `GetElements` RPC in flight while this
/// thread decodes the previous response frame and drains it into the
/// bounded client buffer — so RPC latency overlaps decode + consumption.
/// The internal depth-1 channel plus the request byte budget bound
/// per-worker client memory to roughly two response frames.
fn spawn_batched_fetcher(shared: Arc<FetchShared>, addr: String) {
    shared.active_fetchers.fetch_add(1, Ordering::SeqCst);
    let s2 = shared.clone();
    let spawned = std::thread::Builder::new()
        .name(format!("svc-fetchb-{addr}"))
        .spawn(move || {
            batched_fetch_loop(&s2, &addr);
            s2.active_fetchers.fetch_sub(1, Ordering::SeqCst);
        });
    if spawned.is_err() {
        // Spawn failure must not wedge the supervisor's drain wait.
        shared.active_fetchers.fetch_sub(1, Ordering::SeqCst);
    }
}

fn batched_fetch_loop(shared: &Arc<FetchShared>, addr: &str) {
    let (btx, brx) = chan::bounded::<GetElementsResp>(1);
    // Kept by the drain side solely to force-close the pipeline if it
    // exits early (consumer gone): the blocked requester then unblocks.
    let pipeline_close = btx.clone();

    let req_shared = shared.clone();
    let req_addr = addr.to_string();
    let requester = std::thread::Builder::new()
        .name(format!("svc-fetchb-req-{addr}"))
        .spawn(move || {
            let mut consecutive_errors = 0u32;
            const MAX_CONSECUTIVE_ERRORS: u32 = 25;
            loop {
                if req_shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                let req = GetElementsReq {
                    job_id: req_shared.job_id,
                    client_id: req_shared.client_id,
                    max_elements: req_shared.batch_max_elements,
                    max_bytes: req_shared.batch_max_bytes,
                    poll_ms: req_shared.batch_poll_ms,
                    compression: req_shared.compression,
                };
                let resp: Result<GetElementsResp, _> = call_typed(
                    &req_shared.pool,
                    &req_addr,
                    worker_methods::GET_ELEMENTS,
                    &req,
                    req_shared.timeout,
                );
                req_shared.metrics.counter("client/rpcs").inc();
                match resp {
                    Ok(r) => {
                        consecutive_errors = 0;
                        req_shared.metrics.counter("client/batched_rpcs").inc();
                        let eos = r.end_of_sequence;
                        if btx.send(r).is_err() {
                            break; // drain side gone
                        }
                        if eos {
                            break;
                        }
                    }
                    Err(crate::rpc::RpcError::Remote(msg))
                        if msg.contains(super::ELEMENT_TOO_LARGE_PREFIX) =>
                    {
                        // Terminal, not transient: the legacy batched
                        // plane cannot chunk; surface the explicit error
                        // (satellite of the session redesign — the old
                        // behavior silently skipped the element).
                        let _ = req_shared.tx.send(Err(ServiceError::Other(msg)));
                        req_shared.finished_workers.lock().unwrap().insert(req_addr.clone());
                        break;
                    }
                    Err(e) => {
                        // Transient: the task may not have reached the
                        // worker yet, or the worker is restarting. Retry
                        // with backoff; give up only after sustained
                        // failure (preemption).
                        req_shared.metrics.counter("client/fetch_errors").inc();
                        let _ = e;
                        consecutive_errors += 1;
                        if consecutive_errors >= MAX_CONSECUTIVE_ERRORS {
                            req_shared
                                .finished_workers
                                .lock()
                                .unwrap()
                                .insert(req_addr.clone());
                            break;
                        }
                        if !req_shared.backoff(Duration::from_millis(20)) {
                            break;
                        }
                    }
                }
            }
            // Unblock the drain side whichever way this loop exited.
            btx.close();
        });

    while let Ok(resp) = brx.recv() {
        let eos = resp.end_of_sequence;
        shared.metrics.counter("client/bytes_fetched").add(resp.frame.len() as u64);
        match decode_batch(resp) {
            Ok(elements) => {
                let mut consumer_gone = false;
                for e in elements {
                    shared.metrics.counter("client/elements_fetched").inc();
                    if shared.tx.send(Ok(e)).is_err() {
                        consumer_gone = true;
                        break;
                    }
                }
                if consumer_gone {
                    break;
                }
            }
            Err(e) => {
                if shared.tx.send(Err(e)).is_err() {
                    break;
                }
            }
        }
        if eos {
            shared.finished_workers.lock().unwrap().insert(addr.to_string());
            break;
        }
    }
    pipeline_close.close();
    if let Ok(h) = requester {
        let _ = h.join();
    }
}

/// Outcome of the stream-session handshake against one worker.
enum Handshake {
    /// Negotiated: fetch through the session plane.
    Session(OpenStreamResp),
    /// The worker predates `OpenStream` (it answered "unknown method"):
    /// downgrade to the legacy RPCs.
    Legacy,
    /// Sustained failure (preemption): give up on this worker.
    Failed,
}

/// Open a stream session with retries. The worker may not have received
/// the task yet (it arrives on its next heartbeat), so "unknown job" and
/// transport errors retry with backoff; only the protocol-level "unknown
/// method" answer is a downgrade signal. The backoff waits on `halt`
/// (closed at release), so a stopping client interrupts it instantly.
#[allow(clippy::too_many_arguments)]
fn open_stream(
    pool: &Pool,
    addr: &str,
    job_id: u64,
    client_id: u64,
    max_frame_len: u64,
    consumer_index: Option<u32>,
    timeout: Duration,
    stop: &AtomicBool,
    halt: &chan::Receiver<()>,
) -> Handshake {
    let mut consecutive_errors = 0u32;
    const MAX_CONSECUTIVE_ERRORS: u32 = 25;
    loop {
        if stop.load(Ordering::SeqCst) {
            return Handshake::Failed;
        }
        let req = OpenStreamReq {
            job_id,
            client_id,
            protocol_version: STREAM_PROTOCOL_VERSION,
            capabilities: stream_caps::ALL,
            max_frame_len,
            consumer_index,
        };
        let resp: Result<OpenStreamResp, _> =
            call_typed(pool, addr, worker_methods::OPEN_STREAM, &req, timeout);
        match resp {
            Ok(r) => return Handshake::Session(r),
            Err(crate::rpc::RpcError::Remote(msg)) if msg.contains("unknown method") => {
                return Handshake::Legacy
            }
            Err(_) => {
                consecutive_errors += 1;
                if consecutive_errors >= MAX_CONSECUTIVE_ERRORS {
                    return Handshake::Failed;
                }
                if halt.recv_timeout(Duration::from_millis(20)).is_err() {
                    return Handshake::Failed;
                }
            }
        }
    }
}

/// Stream-session fetcher: handshake first, then the pipelined `Fetch`
/// loop; downgrades to the legacy fetchers against an old worker.
fn spawn_session_fetcher(shared: Arc<FetchShared>, addr: String) {
    shared.active_fetchers.fetch_add(1, Ordering::SeqCst);
    let s2 = shared.clone();
    let spawned = std::thread::Builder::new()
        .name(format!("svc-fetchs-{addr}"))
        .spawn(move || {
            match open_stream(
                &s2.pool,
                &addr,
                s2.job_id,
                s2.client_id,
                s2.max_frame_len,
                None,
                s2.timeout,
                &s2.stop,
                &s2.halt,
            ) {
                Handshake::Session(info) => {
                    s2.metrics.counter("client/stream_sessions").inc();
                    session_fetch_loop(&s2, &addr, info);
                }
                Handshake::Legacy => {
                    // new-client <-> old-worker downgrade path.
                    s2.metrics.counter("client/stream_handshake_downgrades").inc();
                    if s2.batching {
                        batched_fetch_loop(&s2, &addr);
                    } else {
                        single_fetch_loop(&s2, &addr);
                    }
                }
                Handshake::Failed => {
                    s2.finished_workers.lock().unwrap().insert(addr.clone());
                }
            }
            s2.active_fetchers.fetch_sub(1, Ordering::SeqCst);
        });
    if spawned.is_err() {
        // Spawn failure must not wedge the supervisor's drain wait.
        shared.active_fetchers.fetch_sub(1, Ordering::SeqCst);
    }
}

/// What the session requester hands to the drain thread.
enum SessionItem {
    /// A regular batch response (decode the frame).
    Batch(FetchResp),
    /// A fully-reassembled oversized element's encoding.
    Huge(Vec<u8>),
}

/// Client half of the continuation-frame state machine, shared by the
/// independent session requester and the coordinated round fetcher:
/// reassembles one oversized element from seq-tagged frames and holds the
/// release ack the next request must echo. The worker releases its parked
/// element only on an offset tagged with the *matching* seq reaching its
/// length, so a retried (stale) ack can never release or corrupt the next
/// element — the worker just restarts that element's delivery from 0,
/// which `absorb` handles as a fresh buffer.
#[derive(Default)]
struct ChunkReassembler {
    /// `(chunk_seq, bytes received so far)` of the element being rebuilt.
    buf: Option<(u64, Vec<u8>)>,
    /// `(chunk_seq, total len)` of a just-completed element. Kept until
    /// replaced or reset: once the worker has moved on, the seq tag makes
    /// re-sending it a no-op.
    ack: Option<(u64, u64)>,
}

/// Outcome of feeding one continuation frame to [`ChunkReassembler`].
enum ChunkStep {
    /// Frame absorbed; keep fetching.
    Partial,
    /// Element complete: the full encoding, ready to decode. The release
    /// ack is armed for the next request.
    Complete(Vec<u8>),
    /// The worker's frame does not line up with our buffer.
    Desync(String),
}

impl ChunkReassembler {
    /// `(chunk_seq, chunk_offset)` for the next `FetchReq`.
    fn request_fields(&self) -> (u64, u64) {
        if let Some((seq, b)) = &self.buf {
            (*seq, b.len() as u64)
        } else if let Some((seq, len)) = self.ack {
            (seq, len)
        } else {
            (0, 0)
        }
    }

    /// Absorb a continuation frame (caller checked `chunk_total_len > 0`).
    fn absorb(&mut self, r: &FetchResp) -> ChunkStep {
        if r.chunk_offset == 0 {
            // (Re)start: a new element, or the worker restarting delivery
            // after seeing an offset tagged with a stale seq.
            self.buf = Some((r.chunk_seq, Vec::with_capacity(r.chunk_total_len as usize)));
        }
        let Some((seq, buf)) = self.buf.as_mut() else {
            return ChunkStep::Desync(format!(
                "chunked transfer desync: continuation at offset {} with no buffer",
                r.chunk_offset
            ));
        };
        if *seq != r.chunk_seq || r.chunk_offset as usize != buf.len() {
            return ChunkStep::Desync(format!(
                "chunked transfer desync: have {} bytes of element seq {}, worker sent offset \
                 {} of seq {}",
                buf.len(),
                seq,
                r.chunk_offset,
                r.chunk_seq
            ));
        }
        buf.extend_from_slice(&r.frame);
        if (buf.len() as u64) < r.chunk_total_len {
            return ChunkStep::Partial;
        }
        let (seq, done) = self.buf.take().expect("buffer present");
        self.ack = Some((seq, done.len() as u64));
        ChunkStep::Complete(done)
    }

    /// Drop all state (the worker restarted; its parked element is gone).
    fn reset(&mut self) {
        self.buf = None;
        self.ack = None;
    }
}

/// The session `Fetch` pipeline: a requester thread keeps the next RPC in
/// flight (running the AIMD budget loop and reassembling continuation
/// frames) while this thread decodes responses into the bounded client
/// buffer. Mirrors [`batched_fetch_loop`]'s two-thread structure.
fn session_fetch_loop(shared: &Arc<FetchShared>, addr: &str, info: OpenStreamResp) {
    let (btx, brx) = chan::bounded::<SessionItem>(1);
    let pipeline_close = btx.clone();

    let req_shared = shared.clone();
    let req_addr = addr.to_string();
    let requester = std::thread::Builder::new()
        .name(format!("svc-fetchs-req-{addr}"))
        .spawn(move || {
            session_request_loop(&req_shared, &req_addr, info, &btx);
            // Unblock the drain side whichever way the loop exited.
            btx.close();
        });

    while let Ok(item) = brx.recv() {
        match item {
            SessionItem::Batch(resp) => {
                let eos = resp.end_of_sequence;
                shared.metrics.counter("client/bytes_fetched").add(resp.frame.len() as u64);
                match decode_frame(resp.frame, resp.compressed, resp.num_elements) {
                    Ok(elements) => {
                        let mut consumer_gone = false;
                        for e in elements {
                            shared.metrics.counter("client/elements_fetched").inc();
                            if shared.tx.send(Ok(e)).is_err() {
                                consumer_gone = true;
                                break;
                            }
                        }
                        if consumer_gone {
                            break;
                        }
                    }
                    Err(e) => {
                        if shared.tx.send(Err(e)).is_err() {
                            break;
                        }
                    }
                }
                if eos {
                    shared.finished_workers.lock().unwrap().insert(addr.to_string());
                    break;
                }
            }
            SessionItem::Huge(bytes) => {
                shared.metrics.counter("client/bytes_fetched").add(bytes.len() as u64);
                shared.metrics.counter("client/chunked_elements_fetched").inc();
                let decoded = Element::from_bytes(&bytes).map_err(ServiceError::from);
                shared.metrics.counter("client/elements_fetched").inc();
                if shared.tx.send(decoded).is_err() {
                    break;
                }
            }
        }
    }
    pipeline_close.close();
    if let Ok(h) = requester {
        let _ = h.join();
    }
}

/// Requester half of the session pipeline: issues `Fetch` RPCs, runs the
/// AIMD budget loop off the responses' backpressure hints, reassembles
/// continuation frames, and re-handshakes if the worker lost the session
/// (restart). Exits on end-of-sequence, sustained failure, or stop.
fn session_request_loop(
    shared: &Arc<FetchShared>,
    addr: &str,
    mut info: OpenStreamResp,
    btx: &chan::Sender<SessionItem>,
) {
    let adaptive = shared.adaptive_batching
        && info.capabilities & stream_caps::ADAPTIVE_BATCHING != 0;
    // AIMD state starts at the static config (or worker defaults), so
    // adaptive can only improve on the static budgets it would have used.
    let mut cur_elements =
        if shared.batch_max_elements == 0 { 64 } else { shared.batch_max_elements };
    let mut cur_bytes = if shared.batch_max_bytes == 0 { 1 << 20 } else { shared.batch_max_bytes };
    let bytes_cap = AIMD_MAX_BYTES.min(info.max_frame_len);
    // Continuation-frame reassembly + release-ack state.
    let mut chunks = ChunkReassembler::default();

    let mut consecutive_errors = 0u32;
    const MAX_CONSECUTIVE_ERRORS: u32 = 25;
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        let (chunk_seq, chunk_offset) = chunks.request_fields();
        let req = FetchReq {
            session_id: info.session_id,
            max_elements: cur_elements,
            max_bytes: cur_bytes,
            poll_ms: shared.batch_poll_ms,
            compression: shared.compression,
            round: None,
            chunk_seq,
            chunk_offset,
        };
        let resp: Result<FetchResp, _> =
            call_typed(&shared.pool, addr, worker_methods::FETCH, &req, shared.timeout);
        shared.metrics.counter("client/rpcs").inc();
        match resp {
            Ok(r) => {
                consecutive_errors = 0;
                shared.metrics.counter("client/fetch_rpcs").inc();
                if r.chunk_total_len > 0 {
                    shared.metrics.counter("client/chunk_frames").inc();
                    match chunks.absorb(&r) {
                        ChunkStep::Partial => {}
                        ChunkStep::Complete(done) => {
                            if btx.send(SessionItem::Huge(done)).is_err() {
                                break; // drain side gone
                            }
                        }
                        ChunkStep::Desync(msg) => {
                            let _ = shared.tx.send(Err(ServiceError::Other(msg)));
                            shared.finished_workers.lock().unwrap().insert(addr.to_string());
                            break;
                        }
                    }
                    continue;
                }
                if adaptive {
                    aimd_update(&mut cur_elements, &mut cur_bytes, &r, bytes_cap);
                    shared.metrics.gauge("client/adaptive_max_elements").set(cur_elements as i64);
                    shared.metrics.gauge("client/adaptive_max_bytes").set(cur_bytes as i64);
                }
                let eos = r.end_of_sequence;
                if btx.send(SessionItem::Batch(r)).is_err() {
                    break; // drain side gone
                }
                if eos {
                    break;
                }
            }
            Err(crate::rpc::RpcError::Remote(msg))
                if msg.contains("unknown stream session") || msg.contains("unknown job") =>
            {
                // The worker restarted (sessions are worker-local soft
                // state): re-handshake. A partially-reassembled element
                // died with the worker — drop the buffer; the stream
                // keeps its usual worker-failure semantics (at-most-once
                // under preemption).
                chunks.reset();
                match open_stream(
                    &shared.pool,
                    addr,
                    shared.job_id,
                    shared.client_id,
                    shared.max_frame_len,
                    None,
                    shared.timeout,
                    &shared.stop,
                    &shared.halt,
                ) {
                    Handshake::Session(next) => {
                        shared.metrics.counter("client/stream_rehandshakes").inc();
                        info = next;
                    }
                    _ => {
                        shared.finished_workers.lock().unwrap().insert(addr.to_string());
                        break;
                    }
                }
            }
            Err(crate::rpc::RpcError::Remote(msg))
                if msg.contains(super::ELEMENT_TOO_LARGE_PREFIX) =>
            {
                // Terminal: the stream contains an element this session
                // cannot carry (chunking not negotiated). Surface it, and
                // mark this worker done so the supervisor can close the
                // consumer channel instead of leaving next() blocked.
                let _ = shared.tx.send(Err(ServiceError::Other(msg)));
                shared.finished_workers.lock().unwrap().insert(addr.to_string());
                break;
            }
            Err(e) => {
                shared.metrics.counter("client/fetch_errors").inc();
                let _ = e;
                consecutive_errors += 1;
                if consecutive_errors >= MAX_CONSECUTIVE_ERRORS {
                    shared.finished_workers.lock().unwrap().insert(addr.to_string());
                    break;
                }
                if !shared.backoff(Duration::from_millis(20)) {
                    break;
                }
            }
        }
    }
    // Best-effort session teardown (the worker also GCs on release).
    let _: Result<CloseStreamResp, _> = call_typed(
        &shared.pool,
        addr,
        worker_methods::CLOSE_STREAM,
        &CloseStreamReq { session_id: info.session_id },
        Duration::from_secs(2),
    );
}

/// One AIMD step: grow additively while the worker keeps filling our
/// budgets and reports more data ready; halve when a long-poll came back
/// empty (production-bound — small requests keep latency low).
fn aimd_update(cur_elements: &mut u32, cur_bytes: &mut u64, r: &FetchResp, bytes_cap: u64) {
    let hit_element_cap = r.num_elements >= *cur_elements;
    // Compressed frames under-report raw bytes; treat >= 90% as full.
    let hit_byte_cap = (r.frame.len() as u64) * 10 >= *cur_bytes * 9;
    if (hit_element_cap || hit_byte_cap) && r.ready_elements > 0 {
        *cur_elements = (*cur_elements + AIMD_ELEMENTS_STEP).min(AIMD_MAX_ELEMENTS);
        *cur_bytes = (*cur_bytes + AIMD_BYTES_STEP).min(bytes_cap.max(AIMD_MIN_BYTES));
    } else if r.num_elements == 0 && !r.end_of_sequence {
        *cur_elements = (*cur_elements / 2).max(AIMD_MIN_ELEMENTS);
        *cur_bytes = (*cur_bytes / 2).max(AIMD_MIN_BYTES);
    }
}

/// Client side of the frame contract: decompress (if needed), split the
/// frame into element payloads, decode each.
fn decode_frame(frame: Vec<u8>, compressed: bool, num_elements: u32) -> ServiceResult<Vec<Element>> {
    let plain = if compressed { inflate(&frame)? } else { frame };
    let payloads = Vec::<Vec<u8>>::from_bytes(&plain)?;
    if payloads.len() != num_elements as usize {
        return Err(ServiceError::Other(format!(
            "batched frame carried {} elements, header said {}",
            payloads.len(),
            num_elements
        )));
    }
    payloads
        .iter()
        .map(|b| Element::from_bytes(b).map_err(ServiceError::from))
        .collect()
}

fn decode_batch(resp: GetElementsResp) -> ServiceResult<Vec<Element>> {
    decode_frame(resp.frame, resp.compressed, resp.num_elements)
}

/// Outcome of one coordinated-read attempt through the session plane.
enum CoordOutcome {
    Element(Element),
    /// Nothing this attempt (round not materialized / stale session /
    /// transient error): retry.
    Empty,
    Eos,
    /// The owner reports the round already consumed for this slot (a
    /// replaced consumer re-walking its dead predecessor's progress):
    /// resume the round walk at `next` instead of erroring terminally.
    Consumed { next: u64 },
    /// The owner is a pre-session worker: use the legacy `GetElement`
    /// round protocol (sticky per address).
    Legacy,
}

/// Final resolution of one round by [`CoordEngine::fetch_round`].
enum RoundResolution {
    Element(Element),
    Eos,
    /// Skip forward: the round was consumed by this slot's replaced
    /// predecessor; the walk resumes at `next`.
    Skip { next: u64 },
}

/// Parse the `next round {n}` hint a worker appends to its
/// round-consumed protocol errors (see
/// [`crate::service::ROUND_CONSUMED_PREFIX`]).
fn parse_skip_hint(msg: &str) -> Option<u64> {
    let tail = &msg[msg.rfind("next round ")? + "next round ".len()..];
    let digits: String = tail.chars().take_while(char::is_ascii_digit).collect();
    digits.parse().ok()
}

/// Parse the `retry after {n} ms` hint from the dispatcher's admission
/// shed (see [`crate::service::OVERLOADED_PREFIX`]).
fn parse_retry_hint(msg: &str) -> Option<u64> {
    let tail = &msg[msg.rfind("retry after ")? + "retry after ".len()..];
    let digits: String = tail.chars().take_while(char::is_ascii_digit).collect();
    digits.parse().ok()
}

/// The coordinated round-fetch engine (§3.6 with round prefetch): it
/// walks rounds `floor, floor+1, …`, asking each round's lease holder
/// for this consumer's slot and feeding decoded rounds — strictly in
/// order — into a bounded channel. With
/// [`ServiceClientConfig::round_prefetch_depth`] > 0 and every owner
/// granting [`stream_caps::ROUND_PREFETCH`], the engine runs up to
/// `depth` rounds ahead of trainer demand — the fetch for round `r+1`
/// overlaps the trainer consuming round `r`, taking the
/// materialize+RPC+decode round-trip off the step critical path; with
/// [`ServiceClientConfig::concurrent_round_fetch`] the window's rounds
/// are additionally fetched **concurrently across distinct owner
/// workers** ([`run_concurrent`]), one in-flight round per owner, so a
/// k-worker topology overlaps k wire transfers. The moment any owner
/// turns out not to grant the capability (or to be a pre-session
/// worker), the engine downgrades to lock-step: it fetches a round only
/// once the trainer demands it, which is exactly the old behavior.
///
/// This struct is the engine's *shared core* (immutable config + shared
/// gates); the per-connection mutable state lives in [`OwnerLaneState`],
/// one per fetch lane, so concurrent lanes never contend on session or
/// chunk state.
struct CoordEngine {
    pool: Arc<Pool>,
    job_id: u64,
    client_id: u64,
    consumer_index: u32,
    compression: CompressionMode,
    timeout: Duration,
    stream_sessions: bool,
    max_frame_len: u64,
    prefetch_depth: u64,
    /// Demand-gated mode (no fetch-ahead); sticky once set.
    lockstep: AtomicBool,
    shared: Arc<CoordShared>,
    /// The consumer's round cursor (also the heartbeat's `next_round`
    /// progress report). The engine bumps it directly when it *skips*
    /// rounds a replaced predecessor already consumed — the trainer
    /// never sees those rounds, so `next()` cannot account for them,
    /// and without the bump the demand gate would wedge `k` rounds
    /// behind the engine forever.
    delivered: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    halt: chan::Receiver<()>,
    metrics: Registry,
}

/// Per-lane mutable fetch state: negotiated sessions (`None` marks a
/// legacy worker that rejected the handshake — downgrade sticky per
/// address) and continuation-frame reassembly + release-ack state for
/// chunked round slots (see [`ChunkReassembler`]; persistent so a
/// transport retry resumes mid-element instead of desyncing). Keyed by
/// worker address because a lane follows a round's lease wherever it
/// moves.
#[derive(Default)]
struct OwnerLaneState {
    sessions: std::collections::HashMap<String, Option<OpenStreamResp>>,
    chunks: std::collections::HashMap<String, ChunkReassembler>,
}

/// The single-threaded pipelined engine: walk rounds in order with one
/// in-flight fetch at a time, up to the prefetch depth ahead of trainer
/// demand. Also serves as the lock-step engine (depth 0, downgraded, or
/// legacy round protocol) and as the baseline the multi-owner
/// [`run_concurrent`] engine is benchmarked against.
fn run_sequential(
    engine: &CoordEngine,
    start_round: u64,
    tx: chan::Sender<crate::data::DataResult<Option<Element>>>,
) {
    let mut st = OwnerLaneState::default();
    let mut round = start_round;
    loop {
        if !engine.wait_for_demand(round) {
            break; // released
        }
        // Fetch *started* before the trainer demanded the round = the
        // engine ran ahead (a round taken off the step critical
        // path). Snapshot at start: completion-time demand races the
        // trainer's consumption speed and would under-count.
        let ahead = *engine.shared.demand.lock().unwrap() <= round;
        match engine.fetch_round(&mut st, round) {
            Ok(RoundResolution::Element(e)) => {
                if ahead {
                    engine.metrics.counter("client/rounds_prefetched").inc();
                }
                if tx.send(Ok(Some(e))).is_err() {
                    break; // consumer gone
                }
                round += 1;
            }
            Ok(RoundResolution::Skip { next }) => {
                let to = next.max(round + 1);
                engine.note_skip(round, to);
                round = to;
            }
            Ok(RoundResolution::Eos) => {
                let _ = tx.send(Ok(None));
                break;
            }
            Err(e) => {
                let _ = tx.send(Err(e));
                break;
            }
        }
    }
    engine.close_sessions(&st);
}

/// One concurrent fetch lane: serially fetch the rounds the coordinator
/// assigns (normally one owner's residue stream), keeping per-address
/// session and chunk state across rounds.
fn owner_lane_loop(
    engine: Arc<CoordEngine>,
    rx: chan::Receiver<u64>,
    res_tx: chan::Sender<(u64, crate::data::DataResult<Option<Element>>)>,
) {
    let mut st = OwnerLaneState::default();
    while let Ok(round) = rx.recv() {
        let res = engine.fetch_round(&mut st, round);
        if res_tx.send((round, res)).is_err() {
            break; // coordinator gone
        }
        // Completion wakeup: the coordinator sleeps on the demand
        // condvar (no completion poll). Taking the demand lock orders
        // this notify after a coordinator that already drained the
        // result queue and is about to wait — no lost wakeups.
        drop(engine.shared.demand.lock().unwrap());
        engine.shared.demand_changed.notify_all();
    }
    engine.close_sessions(&st);
}

/// Multi-owner concurrent round fetching: the coordinator issues the
/// prefetch window's rounds to per-owner fetch lanes (at most one
/// in-flight round per distinct owner address), reorders completions,
/// and delivers rounds to the trainer channel strictly in order — the
/// §3.6 discipline (each slot fetched exactly once, rounds consumed in
/// order) is untouched; only the *wire transfers* overlap. On a
/// k-worker topology the round cadence approaches `fetch/k` where the
/// single-thread engine was pinned at `fetch`.
fn run_concurrent(
    engine: &Arc<CoordEngine>,
    start_round: u64,
    tx: chan::Sender<crate::data::DataResult<Option<Element>>>,
) {
    let (res_tx, res_rx) = chan::bounded::<(u64, crate::data::DataResult<RoundResolution>)>(16);
    // addr -> (round queue, join handle). Lanes are created on first
    // contact with an owner and live until teardown.
    let mut lanes: std::collections::HashMap<String, (chan::Sender<u64>, std::thread::JoinHandle<()>)> =
        std::collections::HashMap::new();
    // In-flight round -> the owner address fetching it.
    let mut busy: std::collections::HashMap<u64, String> = std::collections::HashMap::new();
    // Completed out-of-order rounds awaiting in-order delivery.
    let mut ready: std::collections::HashMap<u64, crate::data::DataResult<RoundResolution>> =
        std::collections::HashMap::new();
    // Rounds issued before the trainer demanded them (prefetch ledger).
    let mut issued_ahead: HashSet<u64> = HashSet::new();
    let mut next_issue = start_round;
    let mut next_deliver = start_round;
    let depth = engine.prefetch_depth.max(1);
    'outer: while !engine.stop.load(Ordering::SeqCst) {
        // Deliver completed rounds strictly in order.
        while let Some(res) = ready.remove(&next_deliver) {
            match res {
                Ok(RoundResolution::Element(e)) => {
                    if issued_ahead.remove(&next_deliver) {
                        engine.metrics.counter("client/rounds_prefetched").inc();
                    }
                    if tx.send(Ok(Some(e))).is_err() {
                        break 'outer; // consumer gone
                    }
                    next_deliver += 1;
                }
                Ok(RoundResolution::Skip { next }) => {
                    // The round was consumed by this slot's replaced
                    // predecessor: jump the delivery cursor forward.
                    // Rounds already in flight below the new cursor
                    // resolve as skips too and are dropped on arrival.
                    let to = next.max(next_deliver + 1);
                    engine.note_skip(next_deliver, to);
                    next_deliver = to;
                    ready.retain(|&r, _| r >= next_deliver);
                    issued_ahead.retain(|&r| r >= next_deliver);
                    if next_issue < next_deliver {
                        next_issue = next_deliver;
                    }
                }
                Ok(RoundResolution::Eos) => {
                    let _ = tx.send(Ok(None));
                    break 'outer;
                }
                Err(e) => {
                    let _ = tx.send(Err(e));
                    break 'outer;
                }
            }
        }
        // Issue new rounds: up to `depth` ahead of trainer demand, one
        // in-flight round per owner. A mid-flight downgrade (an owner
        // without ROUND_PREFETCH) shrinks the horizon to demanded rounds
        // only; rounds already issued still deliver normally.
        let demand = *engine.shared.demand.lock().unwrap();
        let horizon =
            if engine.lockstep.load(Ordering::SeqCst) { demand } else { demand + depth };
        while next_issue < horizon {
            let Some(addr) = engine.resolve_owner(next_issue) else { break 'outer };
            if busy.values().any(|a| *a == addr) {
                break; // owner busy: its next round waits for this one
            }
            if !lanes.contains_key(&addr) {
                let (ltx, lrx) = chan::bounded::<u64>(1);
                let eng = engine.clone();
                let rtx = res_tx.clone();
                match std::thread::Builder::new()
                    .name(format!("svc-coord-lane-{addr}"))
                    .spawn(move || owner_lane_loop(eng, lrx, rtx))
                {
                    Ok(h) => {
                        lanes.insert(addr.clone(), (ltx, h));
                    }
                    Err(_) => {
                        // Cannot spawn a lane: fetch inline (degraded but
                        // correct — delivery order is unaffected).
                        let mut st = OwnerLaneState::default();
                        let res = engine.fetch_round(&mut st, next_issue);
                        engine.close_sessions(&st);
                        ready.insert(next_issue, res);
                        next_issue += 1;
                        continue;
                    }
                }
            }
            if demand <= next_issue {
                issued_ahead.insert(next_issue);
            }
            let sent = lanes.get(&addr).map(|(ltx, _)| ltx.send(next_issue).is_ok()).unwrap_or(false);
            if !sent {
                // Lane queue closed underneath us: forget it and retry
                // this round on a fresh lane next iteration.
                lanes.remove(&addr);
                issued_ahead.remove(&next_issue);
                continue;
            }
            busy.insert(next_issue, addr);
            next_issue += 1;
        }
        // Event-driven wait: lane completions and trainer demand bumps
        // both land on the demand condvar (a lane notifies after
        // sending its result, `next()` notifies on every demand bump,
        // release notifies on stop), so the coordinator sleeps without
        // a poll tick. Draining under the demand lock closes the race
        // with a lane that completed between the drain and the wait.
        // The long timeout is a watchdog only; its firings are metered
        // and the idle-engine test asserts it stays silent.
        let mut drained = false;
        {
            let d = engine.shared.demand.lock().unwrap();
            while let Some((round, res)) = res_rx.try_recv() {
                busy.remove(&round);
                if round >= next_deliver {
                    ready.insert(round, res);
                }
                drained = true;
            }
            if !drained && !engine.stop.load(Ordering::SeqCst) {
                let (_d, timeout) = engine
                    .shared
                    .demand_changed
                    .wait_timeout(d, Duration::from_secs(5))
                    .unwrap();
                if timeout.timed_out() {
                    engine.metrics.counter("client/round_engine_timer_wakeups").inc();
                }
            }
        }
    }
    // Teardown: closing the round queues ends the lane loops (lanes
    // blocked mid-fetch notice `stop` once the iterator releases); each
    // lane closes its own sessions on exit.
    for (ltx, _) in lanes.values() {
        ltx.close();
    }
    for (_, (_, h)) in lanes {
        let _ = h.join();
    }
}

impl CoordEngine {

    /// Pacing gate: prefetch up to `depth` rounds ahead of trainer
    /// demand; in lock-step (depth 0 or downgraded) wait for the round
    /// to be explicitly demanded. Condvar-driven — `next()` notifies on
    /// every demand bump, release notifies to unblock. Returns false
    /// when the client released.
    fn wait_for_demand(&self, round: u64) -> bool {
        let mut d = self.shared.demand.lock().unwrap();
        loop {
            if self.stop.load(Ordering::SeqCst) {
                return false;
            }
            // Re-read per iteration: a downgrade can land mid-wait.
            let depth =
                if self.lockstep.load(Ordering::SeqCst) { 0 } else { self.prefetch_depth };
            if round < *d + depth {
                return true;
            }
            let (next, _) = self
                .shared
                .demand_changed
                .wait_timeout(d, Duration::from_millis(250))
                .unwrap();
            d = next;
        }
    }

    /// Best-effort teardown of one lane's negotiated sessions (the
    /// worker also GCs them with the consumer's release).
    fn close_sessions(&self, st: &OwnerLaneState) {
        for (addr, info) in st.sessions.iter() {
            if let Some(info) = info {
                let _: Result<CloseStreamResp, _> = call_typed(
                    &self.pool,
                    addr,
                    worker_methods::CLOSE_STREAM,
                    &CloseStreamReq { session_id: info.session_id },
                    Duration::from_secs(2),
                );
            }
        }
    }

    /// Resolve the current lease holder for `round`: the dispatcher's
    /// residue-indexed owner map when present, else the plain worker
    /// list (pre-lease fallback). Blocks (condvar) while the map is
    /// empty; None when the client released.
    fn resolve_owner(&self, round: u64) -> Option<String> {
        let mut o = self.shared.owners.lock().unwrap();
        loop {
            if self.stop.load(Ordering::SeqCst) {
                return None;
            }
            let addrs = if !o.round_owner_addrs.is_empty() {
                &o.round_owner_addrs
            } else {
                &o.worker_addrs
            };
            if !addrs.is_empty() {
                return Some(addrs[(round % addrs.len() as u64) as usize].clone());
            }
            let (next, _) = self
                .shared
                .owners_changed
                .wait_timeout(o, Duration::from_millis(250))
                .unwrap();
            o = next;
        }
    }

    /// Fetch one round to completion: resolve the owner, attempt the
    /// session (or legacy) protocol, re-resolve on churn. Empty attempts
    /// ride the worker-side long-poll; only fast failures (connection
    /// refused while an owner restarts or a lease moves) take a brief
    /// halt-interruptible backoff, so round latency is never quantized
    /// to a sleep.
    fn fetch_round(
        &self,
        st: &mut OwnerLaneState,
        round: u64,
    ) -> crate::data::DataResult<RoundResolution> {
        loop {
            if self.stop.load(Ordering::SeqCst) {
                return Ok(RoundResolution::Eos);
            }
            // A shrink epoch dropped this consumer's slot from `round`
            // on: end cleanly instead of waiting on a round the workers
            // hold no slot for.
            if round >= self.shared.eos_at.load(Ordering::SeqCst) {
                return Ok(RoundResolution::Eos);
            }
            let Some(owner) = self.resolve_owner(round) else {
                return Ok(RoundResolution::Eos);
            };
            let t0 = Instant::now();
            let outcome = if self.stream_sessions {
                self.try_fetch_session(st, round, &owner)?
            } else {
                CoordOutcome::Legacy
            };
            let outcome = match outcome {
                CoordOutcome::Legacy => self.fetch_round_legacy(round, &owner)?,
                other => other,
            };
            match outcome {
                CoordOutcome::Element(e) => return Ok(RoundResolution::Element(e)),
                CoordOutcome::Eos => return Ok(RoundResolution::Eos),
                CoordOutcome::Consumed { next } => {
                    return Ok(RoundResolution::Skip { next });
                }
                CoordOutcome::Empty => {
                    // A slow attempt already waited on the worker's
                    // long-poll; only pace fast failures.
                    if t0.elapsed() < Duration::from_millis(5)
                        && self.halt.recv_timeout(Duration::from_millis(10)).is_err()
                    {
                        return Ok(RoundResolution::Eos);
                    }
                }
                CoordOutcome::Legacy => unreachable!("legacy resolved above"),
            }
        }
    }

    /// Account a skip-forward: rounds `[from, to)` were consumed by
    /// this slot's replaced predecessor and will never reach the
    /// trainer, so the engine advances the shared round cursor itself
    /// (the demand gate and the heartbeat progress report both read
    /// it) and wakes anything parked on the gate.
    fn note_skip(&self, from: u64, to: u64) {
        let k = to.saturating_sub(from);
        if k == 0 {
            return;
        }
        self.metrics.counter("client/rounds_skipped_forward").add(k);
        let want = self.delivered.fetch_add(k, Ordering::SeqCst) + k + 1;
        let mut d = self.shared.demand.lock().unwrap();
        if *d < want {
            *d = want;
            self.shared.demand_changed.notify_all();
        }
    }

    /// One attempt to fetch `round`'s slot from `owner` via
    /// `OpenStream`/`Fetch` (§3.6 one-slot-per-call discipline:
    /// `max_elements` is pinned to 1 by the round read).
    fn try_fetch_session(
        &self,
        st: &mut OwnerLaneState,
        round: u64,
        owner: &str,
    ) -> Result<CoordOutcome, crate::data::DataError> {
        let info = match st.sessions.get(owner) {
            Some(None) => return Ok(CoordOutcome::Legacy),
            Some(Some(info)) => info.clone(),
            None => {
                let req = OpenStreamReq {
                    job_id: self.job_id,
                    client_id: self.client_id,
                    protocol_version: STREAM_PROTOCOL_VERSION,
                    capabilities: stream_caps::ALL,
                    max_frame_len: self.max_frame_len,
                    consumer_index: Some(self.consumer_index),
                };
                match call_typed::<_, OpenStreamResp>(
                    &self.pool,
                    owner,
                    worker_methods::OPEN_STREAM,
                    &req,
                    self.timeout,
                ) {
                    Ok(resp) => {
                        self.metrics.counter("client/stream_sessions").inc();
                        if resp.capabilities & stream_caps::ROUND_PREFETCH == 0 {
                            self.downgrade_to_lockstep();
                        }
                        st.sessions.insert(owner.to_string(), Some(resp.clone()));
                        resp
                    }
                    Err(crate::rpc::RpcError::Remote(msg)) if msg.contains("unknown method") => {
                        self.metrics.counter("client/stream_handshake_downgrades").inc();
                        self.downgrade_to_lockstep();
                        st.sessions.insert(owner.to_string(), None);
                        return Ok(CoordOutcome::Legacy);
                    }
                    Err(_) => return Ok(CoordOutcome::Empty), // task not there yet / restarting
                }
            }
        };
        // Continuation-frame state for this worker: persistent, so a
        // transport retry resumes a chunked round slot mid-element.
        let chunks = st.chunks.entry(owner.to_string()).or_default();
        loop {
            let (chunk_seq, chunk_offset) = chunks.request_fields();
            let req = FetchReq {
                session_id: info.session_id,
                max_elements: 1,
                max_bytes: 0,
                poll_ms: 0,
                compression: self.compression,
                round: Some(round),
                chunk_seq,
                chunk_offset,
            };
            match call_typed::<_, FetchResp>(
                &self.pool,
                owner,
                worker_methods::FETCH,
                &req,
                self.timeout,
            ) {
                Ok(r) => {
                    self.metrics.counter("client/fetch_rpcs").inc();
                    if r.wrong_worker_for_round {
                        return Ok(CoordOutcome::Empty); // stale owner map
                    }
                    if r.chunk_total_len > 0 {
                        self.metrics.counter("client/chunk_frames").inc();
                        match chunks.absorb(&r) {
                            ChunkStep::Partial => continue,
                            ChunkStep::Complete(bytes) => {
                                self.metrics.counter("client/chunked_elements_fetched").inc();
                                let e = Element::from_bytes(&bytes)
                                    .map_err(|e| crate::data::DataError::Other(e.to_string()))?;
                                return Ok(CoordOutcome::Element(e));
                            }
                            ChunkStep::Desync(msg) => {
                                // Clean slate so a retried round can
                                // restart the element from 0.
                                chunks.reset();
                                return Err(crate::data::DataError::Other(msg));
                            }
                        }
                    }
                    if r.num_elements > 0 {
                        let mut elems = decode_frame(r.frame, r.compressed, r.num_elements)
                            .map_err(|e| crate::data::DataError::Other(e.to_string()))?;
                        return Ok(CoordOutcome::Element(elems.remove(0)));
                    }
                    if r.end_of_sequence {
                        return Ok(CoordOutcome::Eos);
                    }
                    return Ok(CoordOutcome::Empty); // round not materialized yet
                }
                Err(crate::rpc::RpcError::Remote(msg))
                    if msg.contains("unknown stream session") || msg.contains("unknown job") =>
                {
                    // Worker restarted: forget the session (and any
                    // half-rebuilt element that died with it),
                    // re-handshake on the next attempt.
                    st.sessions.remove(owner);
                    chunks.reset();
                    return Ok(CoordOutcome::Empty);
                }
                Err(crate::rpc::RpcError::Remote(msg))
                    if msg.contains(crate::service::ROUND_CONSUMED_PREFIX) =>
                {
                    // The round (or this slot in it) was already
                    // consumed — a replaced consumer re-walking its
                    // dead predecessor's progress. The worker names the
                    // resume point; skip forward instead of surfacing a
                    // terminal error.
                    let next = parse_skip_hint(&msg).unwrap_or(round + 1);
                    return Ok(CoordOutcome::Consumed { next });
                }
                Err(crate::rpc::RpcError::Remote(msg)) => {
                    // Other protocol-level round errors (consumer-index
                    // mismatch, malformed request): terminal — retrying
                    // would loop forever.
                    return Err(crate::data::DataError::Other(msg));
                }
                Err(_) => return Ok(CoordOutcome::Empty),
            }
        }
    }

    /// The legacy `GetElement` round protocol against a pre-session
    /// worker.
    fn fetch_round_legacy(
        &self,
        round: u64,
        owner: &str,
    ) -> Result<CoordOutcome, crate::data::DataError> {
        let req = GetElementReq {
            job_id: self.job_id,
            client_id: self.client_id,
            consumer_index: Some(self.consumer_index),
            round: Some(round),
            compression: self.compression,
        };
        let resp: Result<GetElementResp, _> =
            call_typed(&self.pool, owner, worker_methods::GET_ELEMENT, &req, self.timeout);
        self.metrics.counter("client/rpcs").inc();
        match resp {
            Ok(r) if r.end_of_sequence => Ok(CoordOutcome::Eos),
            Ok(r) => match r.element {
                Some(bytes) => {
                    let e = decode_element(&bytes, r.compressed)
                        .map_err(|e| crate::data::DataError::Other(e.to_string()))?;
                    Ok(CoordOutcome::Element(e))
                }
                None => Ok(CoordOutcome::Empty),
            },
            Err(crate::rpc::RpcError::Remote(msg))
                if msg.contains(crate::service::ROUND_CONSUMED_PREFIX) =>
            {
                // Same skip-forward protocol on the legacy round path.
                let next = parse_skip_hint(&msg).unwrap_or(round + 1);
                Ok(CoordOutcome::Consumed { next })
            }
            Err(_) => Ok(CoordOutcome::Empty),
        }
    }

    /// Sticky downgrade to the lock-step discipline (an owner without
    /// [`stream_caps::ROUND_PREFETCH`], or a pre-session worker).
    /// Atomic: concurrent lanes may discover it simultaneously, and the
    /// counter must move once.
    fn downgrade_to_lockstep(&self) {
        if !self.lockstep.swap(true, Ordering::SeqCst) {
            self.metrics.counter("client/round_prefetch_downgrades").inc();
        }
    }
}

fn decode_element(bytes: &[u8], compressed: bool) -> ServiceResult<Element> {
    let plain;
    let slice = if compressed {
        plain = inflate(bytes)?;
        &plain[..]
    } else {
        bytes
    };
    Ok(Element::from_bytes(slice)?)
}

impl ElemIter for DistributedIter {
    fn next(&mut self) -> DataResult<Option<Element>> {
        match self.mode {
            ProcessingMode::Independent => {
                let rx = self.rx.as_ref().expect("independent iter has rx");
                // Stall accounting (autoscaler input): an element already
                // buffered means the input pipeline kept up; an empty
                // buffer means this `next()` stalls the training step.
                let first = rx.try_recv();
                self.stall.record(first.is_none());
                let got = match first {
                    Some(v) => Ok(v),
                    None => rx.recv(),
                };
                match got {
                    Ok(Ok(e)) => Ok(Some(e)),
                    Ok(Err(e)) => Err(crate::data::DataError::Other(e.to_string())),
                    Err(_) => Ok(None),
                }
            }
            ProcessingMode::Coordinated => {
                let coord = self.coord.as_mut().expect("coordinated iter");
                if coord.finished {
                    return Ok(None);
                }
                // Announce demand for the next round — wakes a lock-step
                // engine; a prefetching engine is already ahead and the
                // round is typically sitting in the channel.
                coord.announce_demand();
                // Stall accounting, as in the independent arm: a round
                // already prefetched into the channel is a hit.
                let first = coord.rx.try_recv();
                self.stall.record(first.is_none());
                let got = match first {
                    Some(v) => Ok(v),
                    None => coord.rx.recv_timeout(coord.timeout),
                };
                match got {
                    Ok(Some(Ok(Some(e)))) => {
                        coord.delivered.fetch_add(1, Ordering::SeqCst);
                        Ok(Some(e))
                    }
                    Ok(Some(Ok(None))) => {
                        coord.finished = true;
                        Ok(None)
                    }
                    Ok(Some(Err(e))) => {
                        coord.finished = true;
                        Err(e)
                    }
                    Ok(None) => Err(crate::data::DataError::Other(format!(
                        "coordinated round {} timed out",
                        coord.delivered.load(Ordering::SeqCst)
                    ))),
                    // Engine exited (stop/eos already delivered).
                    Err(_) => {
                        coord.finished = true;
                        Ok(None)
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::Server;
    use crate::wire::Encode;

    fn probe(addr: &str) -> Handshake {
        let pool = Pool::with_defaults();
        let stop = AtomicBool::new(false);
        let (_halt_tx, halt_rx) = chan::bounded::<()>(1);
        open_stream(&pool, addr, 1, 2, 0, None, Duration::from_secs(2), &stop, &halt_rx)
    }

    /// new-client <-> old-worker: a worker that predates the session
    /// protocol answers its method demux's "unknown method" error, and
    /// the client must downgrade to the legacy RPCs, not retry.
    #[test]
    fn handshake_downgrades_against_pre_session_worker() {
        let srv = Server::bind("127.0.0.1:0", |method: u16, _p: &[u8]| {
            Err(format!("worker: unknown method {method}"))
        })
        .unwrap();
        assert!(matches!(probe(&srv.local_addr().to_string()), Handshake::Legacy));
    }

    /// The handshake against a session worker returns the worker's
    /// negotiated answer verbatim.
    #[test]
    fn handshake_accepts_negotiated_session() {
        let srv = Server::bind("127.0.0.1:0", |method: u16, p: &[u8]| {
            assert_eq!(method, worker_methods::OPEN_STREAM);
            let req = OpenStreamReq::from_bytes(p).map_err(|e| e.to_string())?;
            assert_eq!(req.protocol_version, STREAM_PROTOCOL_VERSION);
            assert_eq!(req.capabilities, stream_caps::ALL);
            Ok(OpenStreamResp {
                session_id: 7,
                protocol_version: req.protocol_version.min(STREAM_PROTOCOL_VERSION),
                capabilities: req.capabilities & stream_caps::DEFLATE,
                max_frame_len: 1 << 20,
                mode: ProcessingMode::Independent,
            }
            .to_bytes()
            .into())
        })
        .unwrap();
        match probe(&srv.local_addr().to_string()) {
            Handshake::Session(info) => {
                assert_eq!(info.session_id, 7);
                assert_eq!(info.capabilities, stream_caps::DEFLATE);
            }
            _ => panic!("expected a negotiated session"),
        }
    }

    /// A worker that keeps answering "unknown job" (task not delivered)
    /// is retried, and the handshake aborts promptly once stop is set.
    #[test]
    fn handshake_respects_stop() {
        let srv =
            Server::bind("127.0.0.1:0", |_m: u16, _p: &[u8]| Err("unknown job 1".to_string()))
                .unwrap();
        let pool = Pool::with_defaults();
        let stop = Arc::new(AtomicBool::new(false));
        let (halt_tx, halt_rx) = chan::bounded::<()>(1);
        let s2 = stop.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(60));
            s2.store(true, Ordering::SeqCst);
            // The halt channel is what interrupts an in-progress backoff.
            halt_tx.close();
        });
        let t0 = Instant::now();
        let h = open_stream(
            &pool,
            &srv.local_addr().to_string(),
            1,
            2,
            0,
            None,
            Duration::from_secs(2),
            &stop,
            &halt_rx,
        );
        assert!(matches!(h, Handshake::Failed));
        assert!(t0.elapsed() < Duration::from_secs(2), "stop cut the retry loop short");
    }

    fn full_resp(num: u32, frame_len: usize, ready: u32) -> FetchResp {
        FetchResp {
            num_elements: num,
            compressed: false,
            end_of_sequence: false,
            wrong_worker_for_round: false,
            chunk_seq: 0,
            chunk_offset: 0,
            chunk_total_len: 0,
            ready_elements: ready,
            window_elements: ready,
            window_bytes: 0,
            frame: vec![0u8; frame_len],
        }
    }

    fn chunk_resp(seq: u64, offset: u64, total: u64, frame: Vec<u8>) -> FetchResp {
        FetchResp {
            chunk_seq: seq,
            chunk_offset: offset,
            chunk_total_len: total,
            frame,
            ..full_resp(0, 0, 0)
        }
    }

    /// The reassembler's seq-tagged state machine: normal reassembly,
    /// ack arming, a stale-ack-triggered restart (the worker re-serving
    /// a *new* element from 0 while we still echo the old ack), and the
    /// desync verdicts.
    #[test]
    fn chunk_reassembler_state_machine() {
        let mut c = ChunkReassembler::default();
        assert_eq!(c.request_fields(), (0, 0));
        // Element seq 1, total 5, in frames of 2/2/1.
        assert!(matches!(c.absorb(&chunk_resp(1, 0, 5, vec![1, 2])), ChunkStep::Partial));
        assert_eq!(c.request_fields(), (1, 2));
        assert!(matches!(c.absorb(&chunk_resp(1, 2, 5, vec![3, 4])), ChunkStep::Partial));
        match c.absorb(&chunk_resp(1, 4, 5, vec![5])) {
            ChunkStep::Complete(done) => assert_eq!(done, vec![1, 2, 3, 4, 5]),
            _ => panic!("expected completion"),
        }
        // Ack armed: the next request echoes (seq, total).
        assert_eq!(c.request_fields(), (1, 5));
        // The worker parked a NEW element and answered our (stale) ack by
        // starting it from 0: a fresh buffer, no misattribution.
        assert!(matches!(c.absorb(&chunk_resp(2, 0, 4, vec![9, 9])), ChunkStep::Partial));
        assert_eq!(c.request_fields(), (2, 2));
        // A frame for a different element mid-buffer is a desync...
        assert!(matches!(c.absorb(&chunk_resp(3, 2, 4, vec![8])), ChunkStep::Desync(_)));
        // ...as is a non-contiguous offset for the right element.
        assert!(matches!(c.absorb(&chunk_resp(2, 3, 4, vec![8])), ChunkStep::Desync(_)));
        c.reset();
        assert_eq!(c.request_fields(), (0, 0));
        // A continuation frame at a non-zero offset with no buffer (e.g.
        // after a reset) is a desync, not a crash.
        assert!(matches!(c.absorb(&chunk_resp(2, 2, 4, vec![8])), ChunkStep::Desync(_)));
    }

    #[test]
    fn chunk_reassembler_handles_worker_restarting_delivery() {
        let mut c = ChunkReassembler::default();
        assert!(matches!(c.absorb(&chunk_resp(1, 0, 4, vec![1, 2])), ChunkStep::Partial));
        // Worker restarted delivery from 0 (it saw a stale seq from us):
        // offset 0 always starts a fresh buffer, even mid-element.
        assert!(matches!(c.absorb(&chunk_resp(1, 0, 4, vec![1, 2])), ChunkStep::Partial));
        match c.absorb(&chunk_resp(1, 2, 4, vec![3, 4])) {
            ChunkStep::Complete(done) => assert_eq!(done, vec![1, 2, 3, 4]),
            _ => panic!("expected completion"),
        }
    }

    #[test]
    fn aimd_grows_on_full_responses_and_halves_on_empty() {
        let mut e = 64u32;
        let mut b = 1u64 << 20;
        // Full response + more ready: additive increase on both axes.
        aimd_update(&mut e, &mut b, &full_resp(64, 1 << 20, 10), AIMD_MAX_BYTES);
        assert_eq!(e, 64 + AIMD_ELEMENTS_STEP);
        assert_eq!(b, (1 << 20) + AIMD_BYTES_STEP);
        // Full but nothing more ready: hold (growing would just wait).
        let (e0, b0) = (e, b);
        aimd_update(&mut e, &mut b, &full_resp(e0, 1 << 20, 0), AIMD_MAX_BYTES);
        assert_eq!((e, b), (e0, b0));
        // Partial response: hold.
        aimd_update(&mut e, &mut b, &full_resp(1, 128, 5), AIMD_MAX_BYTES);
        assert_eq!((e, b), (e0, b0));
        // Empty long-poll expiry: multiplicative decrease.
        aimd_update(&mut e, &mut b, &full_resp(0, 4, 0), AIMD_MAX_BYTES);
        assert_eq!(e, e0 / 2);
        assert_eq!(b, b0 / 2);
        // Bounds hold under sustained pressure in both directions.
        for _ in 0..100 {
            aimd_update(&mut e, &mut b, &full_resp(0, 4, 0), AIMD_MAX_BYTES);
        }
        assert_eq!((e, b), (AIMD_MIN_ELEMENTS, AIMD_MIN_BYTES));
        for _ in 0..100 {
            let full = full_resp(e, 0, 99); // element cap hit; frame size immaterial
            aimd_update(&mut e, &mut b, &full, AIMD_MAX_BYTES);
        }
        assert_eq!((e, b), (AIMD_MAX_ELEMENTS, AIMD_MAX_BYTES));
        // A capped byte budget (small negotiated frame) is respected.
        let mut b2 = 256u64 << 10;
        let mut e2 = 64u32;
        aimd_update(&mut e2, &mut b2, &full_resp(64, 256 << 10, 9), 300 << 10);
        assert_eq!(b2, 300 << 10);
    }

    #[test]
    fn aimd_empty_eos_does_not_decay() {
        let mut e = 64u32;
        let mut b = 1u64 << 20;
        let mut r = full_resp(0, 4, 0);
        r.end_of_sequence = true;
        aimd_update(&mut e, &mut b, &r, AIMD_MAX_BYTES);
        assert_eq!((e, b), (64, 1 << 20));
    }
}
