//! Fig. 10: normalized preprocessing cost of hyperparameter-tuning jobs
//! under deployment modes A (shared + sharing), B (shared, no sharing),
//! C (dedicated per job), for k in {1,2,4,8,16}.
//!
//! Paper: A flat at 1x (tested to 64 jobs); B fine to 4 jobs then job
//! time grows 1.75x @ 8 and 3x @ 16; C cost grows linearly. Includes a
//! live sliding-window-cache measurement backing mode A's flatness.

use std::sync::Arc;
use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::metrics::write_csv_rows;
use tfdatasvc::orchestrator::Cell;
use tfdatasvc::rpc::{call_typed, Pool};
use tfdatasvc::service::dispatcher::DispatcherConfig;
use tfdatasvc::service::proto::{worker_methods, ShardingPolicy, WorkerStatusReq, WorkerStatusResp};
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::sim::models::model;
use tfdatasvc::sim::sharing::{mode_a, mode_b, mode_c, sequential_sharing_cost, SharingConfig};
use tfdatasvc::storage::dataset::{generate_vision, VisionGenConfig};
use tfdatasvc::storage::ObjectStore;

fn main() {
    let m = model("M4");
    let cfg = SharingConfig::default();
    println!("=== Fig 10: preprocessing cost by deployment mode ===");
    println!("{:>4} {:>12} {:>12} {:>12} {:>14}", "k", "A(shared)", "B(no share)", "C(dedicated)", "B slowdown");
    let mut rows = Vec::new();
    for k in [1usize, 2, 4, 8, 16] {
        let a = mode_a(m, &cfg, k);
        let b = mode_b(m, &cfg, k);
        let c = mode_c(m, &cfg, k);
        println!(
            "{:>4} {:>12.2} {:>12.2} {:>12.2} {:>13.2}x",
            k,
            a.preprocessing_cost,
            b.preprocessing_cost,
            c.preprocessing_cost,
            1.0 / b.per_job_throughput_frac
        );
        rows.push(vec![
            k.to_string(),
            format!("{:.3}", a.preprocessing_cost),
            format!("{:.3}", b.preprocessing_cost),
            format!("{:.3}", c.preprocessing_cost),
        ]);
    }
    // Paper anchor points.
    let b8 = mode_b(m, &cfg, 8);
    let b16 = mode_b(m, &cfg, 16);
    assert!((1.0 / b8.per_job_throughput_frac - 1.75).abs() < 0.3);
    assert!((1.0 / b16.per_job_throughput_frac - 3.0).abs() < 0.35);
    assert_eq!(mode_a(m, &cfg, 64).preprocessing_cost, 1.0, "A flat to 64 jobs");
    println!(
        "worst-case sequential sharing (cache 1% of dataset, k=16): {:.2}x of one job's cost (vs 16x unshared)",
        sequential_sharing_cost(16, 0.01, 1.0)
    );
    write_csv_rows("out/fig10.csv", "k,mode_a_cost,mode_b_cost,mode_c_cost", &rows).unwrap();

    // ---- Live backing measurement: k clients, one shared job ----
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: 4, samples_per_shard: 32, ..Default::default() },
    );
    let total = spec.total_samples;
    let cell = Arc::new(Cell::new(store, UdfRegistry::with_builtins(), DispatcherConfig::default()).unwrap());
    cell.set_worker_config_mutator(|c| c.cache_window = 4096);
    cell.scale_to(1).unwrap();
    let graph = PipelineBuilder::source_vision(spec).batch(8).build();
    let k = 4;
    let handles: Vec<_> = (0..k)
        .map(|_| {
            let d = cell.dispatcher_addr();
            let g = graph.clone();
            std::thread::spawn(move || {
                let c = ServiceClient::new(&d);
                let mut it = c
                    .distribute(
                        &g,
                        ServiceClientConfig {
                            sharding: ShardingPolicy::Dynamic,
                            job_name: "fig10".into(),
                            ..Default::default()
                        },
                    )
                    .unwrap();
                let mut n = 0;
                while let Ok(Some(_)) = it.next() {
                    n += 1;
                }
                n
            })
        })
        .collect();
    let consumed: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let pool = Pool::with_defaults();
    let status: WorkerStatusResp = call_typed(
        &pool,
        &cell.worker_addrs()[0],
        worker_methods::WORKER_STATUS,
        &WorkerStatusReq {},
        std::time::Duration::from_secs(5),
    )
    .unwrap();
    println!(
        "live: {k} clients consumed {consumed} batches; worker produced {} (sharing factor {:.1}x)",
        status.elements_produced,
        consumed as f64 / status.elements_produced as f64
    );
    assert_eq!(status.elements_produced as usize, total / 8, "produced exactly once");
    assert_eq!(consumed, k * total / 8, "served k times");
    println!("fig10 OK -> out/fig10.csv");
}
