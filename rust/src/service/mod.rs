//! tf.data service: the paper's system contribution.
//!
//! A disaggregated input-data-processing service (§3):
//!
//! * [`dispatcher`] — metadata plane: dataset registry, worker/client
//!   registry, task assignment, dynamic split distribution, heartbeats.
//!   Performs **no data processing** (§3.1).
//! * [`worker`] — data plane: executes pipeline graphs, buffers batches,
//!   serves client `GetElement` RPCs. Hosts the **ephemeral sliding-window
//!   cache** (§3.5) and the **coordinated-reads** round-robin scheduler
//!   (§3.6).
//! * [`client`] — accelerator-host side: registers pipelines, discovers
//!   workers, fetches batches in parallel into a client-side buffer.
//! * [`sharding`] — OFF / DYNAMIC / STATIC source-data sharding (§3.3).
//! * [`journal`] — dispatcher write-ahead journal + replay (§3.4).
//! * [`visitation`] — data-visitation-guarantee trackers used by tests
//!   (exactly-once / at-most-once / zero-once-or-more).
//! * [`proto`] — the RPC schema all of the above speak.

pub mod client;
pub mod dispatcher;
pub mod journal;
pub mod proto;
pub mod sharding;
pub mod visitation;
pub mod worker;

pub use client::{ServiceClient, ServiceClientConfig};
pub use dispatcher::Dispatcher;
pub use proto::{CompressionMode, ProcessingMode, ShardingPolicy};
pub use worker::Worker;

/// Number of source shards in a pipeline graph (drives split tracking and
/// OFF-mode shuffled iteration).
pub fn graph_num_shards(graph: &crate::data::graph::GraphDef) -> usize {
    use crate::data::graph::Node;
    match graph.nodes.first() {
        Some(Node::SourceVision { spec }) | Some(Node::SourceText { spec }) => spec.shards.len(),
        _ => 1,
    }
}

/// Service-level errors.
#[derive(Debug, thiserror::Error)]
pub enum ServiceError {
    #[error("rpc: {0}")]
    Rpc(#[from] crate::rpc::RpcError),
    #[error("wire: {0}")]
    Wire(#[from] crate::wire::WireError),
    #[error("data: {0}")]
    Data(#[from] crate::data::DataError),
    #[error("journal: {0}")]
    Journal(String),
    #[error("unknown dataset {0}")]
    UnknownDataset(u64),
    #[error("unknown job {0}")]
    UnknownJob(u64),
    #[error("unknown worker {0}")]
    UnknownWorker(u64),
    #[error("{0}")]
    Other(String),
}

pub type ServiceResult<T> = Result<T, ServiceError>;
