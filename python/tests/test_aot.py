"""AOT pipeline: artifacts emit, manifest is consistent, HLO text is sane."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    cfg = model.ModelConfig(
        vocab=256, d_model=32, n_layers=1, n_heads=2, d_ff=64, seq_len=16, batch=2
    )
    manifest = aot.emit(out, cfg)
    return out, manifest, cfg


def test_all_artifact_files_exist(emitted):
    out, manifest, _ = emitted
    for name, meta in manifest["artifacts"].items():
        path = os.path.join(out, meta["file"])
        assert os.path.exists(path), name
        assert os.path.getsize(path) == meta["bytes"]


def test_hlo_text_has_entry_computation(emitted):
    out, manifest, _ = emitted
    for meta in manifest["artifacts"].values():
        text = open(os.path.join(out, meta["file"])).read()
        assert "ENTRY" in text
        assert "HloModule" in text


def test_manifest_roundtrips_as_json(emitted):
    out, manifest, _ = emitted
    loaded = json.load(open(os.path.join(out, "manifest.json")))
    assert loaded == manifest


def test_train_step_input_arity(emitted):
    _, manifest, cfg = emitted
    n_params = len(model.param_shapes(cfg))
    # params + tokens + lr
    assert len(manifest["artifacts"]["train_step"]["inputs"]) == n_params + 2


def test_params_init_has_no_inputs(emitted):
    _, manifest, _ = emitted
    assert manifest["artifacts"]["params_init"]["inputs"] == []


def test_manifest_declares_param_shapes_in_order(emitted):
    _, manifest, cfg = emitted
    declared = [
        (e["name"], tuple(e["shape"])) for e in manifest["model"]["param_shapes"]
    ]
    assert declared == [(n, tuple(s)) for n, s in model.param_shapes(cfg)]


def test_vision_artifact_shapes_match_constants(emitted):
    _, manifest, _ = emitted
    vis = manifest["artifacts"]["preprocess_vision"]["inputs"]
    assert vis[0]["dtype"] == "u8"
    assert vis[0]["shape"] == [
        model.VISION_BATCH,
        model.VISION_HW,
        model.VISION_HW,
        model.VISION_C,
    ]


def test_pallas_kernel_lowered_without_custom_calls(emitted):
    """interpret=True must lower to plain HLO the CPU PJRT client can run —
    a mosaic/tpu custom-call here would break the Rust runtime."""
    out, manifest, _ = emitted
    for name in ("preprocess_vision", "train_step"):
        text = open(os.path.join(out, manifest["artifacts"][name]["file"])).read()
        assert "mosaic" not in text.lower(), name
