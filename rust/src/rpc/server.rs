//! RPC server: accept loop, per-connection reader, handler dispatch.
//!
//! Each accepted connection gets a reader thread; each request is handled on
//! a small per-connection worker pool so a slow handler does not serialize
//! the connection (mirrors gRPC's concurrent streams per HTTP/2 connection).
//! Responses from concurrent handlers interleave on the socket, serialized
//! by a write-side mutex; the client re-associates them by call id.

use super::frame::{Frame, FrameKind};
use std::io::{self, BufReader};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Response body as write slices. `head ++ tail` is the logical payload;
/// handlers serving bulk data (the batched `GetElements` plane) put the
/// fixed-size message head in `head` and move the multi-megabyte frame
/// into `tail`, and the server writes both with one scatter-gather frame
/// write — the bulk bytes are never copied into a contiguous response.
/// Plain handlers just convert their encoded message via `From<Vec<u8>>`.
#[derive(Debug, Default)]
pub struct RespBody {
    pub head: Vec<u8>,
    pub tail: Vec<u8>,
}

impl From<Vec<u8>> for RespBody {
    fn from(head: Vec<u8>) -> RespBody {
        RespBody { head, tail: Vec::new() }
    }
}

impl RespBody {
    pub fn parts(head: Vec<u8>, tail: Vec<u8>) -> RespBody {
        RespBody { head, tail }
    }
}

/// A request handler: `(method, payload) -> Ok(response body) | Err(message)`.
/// Must be cheap to clone-share across connections (we wrap it in an `Arc`).
pub trait Handler: Send + Sync + 'static {
    fn handle(&self, method: u16, payload: &[u8]) -> Result<RespBody, String>;
}

impl<F> Handler for F
where
    F: Fn(u16, &[u8]) -> Result<RespBody, String> + Send + Sync + 'static,
{
    fn handle(&self, method: u16, payload: &[u8]) -> Result<RespBody, String> {
        self(method, payload)
    }
}

/// Listening RPC server. Dropping the server stops the accept loop and
/// closes all live connections.
pub struct Server {
    local_addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    active_connections: Arc<AtomicUsize>,
    live_streams: Arc<Mutex<Vec<TcpStream>>>,
}

impl Server {
    /// Bind to `addr` (use port 0 for an ephemeral port) and start serving
    /// `handler` on a background accept thread.
    pub fn bind<H: Handler>(addr: &str, handler: H) -> io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let active = Arc::new(AtomicUsize::new(0));
        let live: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let handler = Arc::new(handler);

        let sd = shutdown.clone();
        let act = active.clone();
        let live2 = live.clone();
        let accept_thread = std::thread::Builder::new()
            .name(format!("rpc-accept-{local_addr}"))
            .spawn(move || {
                for stream in listener.incoming() {
                    if sd.load(Ordering::SeqCst) {
                        break;
                    }
                    match stream {
                        Ok(s) => {
                            if let Ok(clone) = s.try_clone() {
                                live2.lock().unwrap().push(clone);
                            }
                            let h = handler.clone();
                            let sd2 = sd.clone();
                            let act2 = act.clone();
                            act2.fetch_add(1, Ordering::SeqCst);
                            std::thread::Builder::new()
                                .name("rpc-conn".into())
                                .spawn(move || {
                                    let _ = serve_connection(s, h, sd2);
                                    act2.fetch_sub(1, Ordering::SeqCst);
                                })
                                .ok();
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn accept thread");

        Ok(Server {
            local_addr,
            shutdown,
            accept_thread: Some(accept_thread),
            active_connections: active,
            live_streams: live,
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn active_connections(&self) -> usize {
        self.active_connections.load(Ordering::SeqCst)
    }

    /// Request shutdown: stop accepting and sever live connections so
    /// clients observe `ConnectionClosed` promptly (the paper's worker
    /// preemption path relies on fast failure detection).
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for s in self.live_streams.lock().unwrap().drain(..) {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        // Poke the accept loop so `incoming()` returns.
        let _ = TcpStream::connect(self.local_addr);
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Per-connection loop: read frames, dispatch each request on its own
/// thread (cheap on Linux; request concurrency is bounded by the client's
/// in-flight window), write responses under a shared write lock.
fn serve_connection(
    stream: TcpStream,
    handler: Arc<dyn Handler>,
    shutdown: Arc<AtomicBool>,
) -> io::Result<()> {
    stream.set_nodelay(true).ok();
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let mut reader = BufReader::with_capacity(256 << 10, stream);

    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        let frame = match Frame::read_from(&mut reader) {
            Ok(f) => f,
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(()), // peer closed
            Err(e) => return Err(e),
        };
        if frame.kind != FrameKind::Request {
            // Ignore stray non-request frames rather than killing the link.
            continue;
        }
        let h = handler.clone();
        let w = writer.clone();
        std::thread::Builder::new()
            .name("rpc-handler".into())
            .spawn(move || {
                let Frame { call_id, method, payload, .. } = frame;
                // Contain handler panics: report as a Remote error so one
                // buggy request cannot poison the connection.
                let result = std::panic::catch_unwind(AssertUnwindSafe(|| h.handle(method, &payload)))
                    .unwrap_or_else(|p| {
                        let msg = p
                            .downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| p.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "handler panicked".into());
                        Err(format!("panic: {msg}"))
                    });
                if let Ok(mut guard) = w.lock() {
                    let _ = match result {
                        // Gathered write: head and tail go to the socket
                        // as separate slices of one frame (zero-copy for
                        // bulk-data responses).
                        Ok(body) => Frame::write_parts_to(
                            &mut *guard,
                            call_id,
                            FrameKind::Response,
                            method,
                            &[&body.head, &body.tail],
                        ),
                        Err(msg) => Frame::error(call_id, method, &msg).write_to(&mut *guard),
                    };
                }
            })
            .ok();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn ephemeral_bind_and_shutdown() {
        let srv = Server::bind("127.0.0.1:0", |_m, p: &[u8]| Ok(p.to_vec().into())).unwrap();
        let addr = srv.local_addr();
        assert_ne!(addr.port(), 0);
        srv.shutdown();
        // After shutdown new connections are not served.
        std::thread::sleep(Duration::from_millis(50));
    }

    #[test]
    fn connection_counter_tracks() {
        let srv = Server::bind("127.0.0.1:0", |_m, p: &[u8]| Ok(p.to_vec().into())).unwrap();
        assert_eq!(srv.active_connections(), 0);
        let c = super::super::Client::connect(&srv.local_addr().to_string(), Duration::from_secs(1)).unwrap();
        c.call(1, b"x", Duration::from_secs(1)).unwrap();
        assert_eq!(srv.active_connections(), 1);
        drop(c);
        // reader thread notices EOF and decrements
        for _ in 0..100 {
            if srv.active_connections() == 0 {
                return;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        panic!("connection never drained");
    }
}
