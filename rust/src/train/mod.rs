//! Trainer harness: the "client" side ML computation.
//!
//! Consumes pipeline output (local or distributed) and models — or really
//! runs — the accelerator step:
//!
//! * [`StepModel`] — a calibrated accelerator step-time model. For NLP
//!   models the step time scales with the *padded* token count, which is
//!   precisely what makes unpadded-size imbalance cause stragglers (§3.6).
//! * [`SyncTrainer`] — synchronous data-parallel training across N client
//!   iterators with a per-step barrier: the step time is the *max* over
//!   clients (the straggler effect), plus a synchronization overhead.
//! * [`PjrtTrainStep`] — the real thing: the AOT transformer train step
//!   executed through [`crate::runtime::Engine`] (used by
//!   `examples/e2e_train.rs`).

use crate::data::element::{DType, Element, Tensor};
use crate::data::exec::ElemIter;
use crate::data::DataResult;
use crate::metrics::Registry;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

/// Accelerator step-time model.
#[derive(Debug, Clone)]
pub struct StepModel {
    /// Fixed per-step cost (kernel launch, optimizer, collectives).
    pub base: Duration,
    /// Additional cost per padded token in the batch (NLP compute scales
    /// with padded size; 0 for fixed-shape vision models).
    pub per_token: Duration,
    /// Whether to actually sleep (live harness) or just account (sim).
    pub realtime: bool,
}

impl StepModel {
    pub fn fixed(base: Duration) -> StepModel {
        StepModel { base, per_token: Duration::ZERO, realtime: true }
    }

    pub fn tokens_scaled(base: Duration, per_token: Duration) -> StepModel {
        StepModel { base, per_token, realtime: true }
    }

    /// Padded token count of a batched element (batch × padded length).
    pub fn padded_tokens(elem: &Element) -> u64 {
        match elem.tensors.first() {
            Some(t) if t.rank() >= 2 => (t.shape[0] * t.shape[1]) as u64,
            Some(t) if t.rank() == 1 => t.shape[0] as u64,
            _ => 0,
        }
    }

    pub fn step_time(&self, elem: &Element) -> Duration {
        self.base + self.per_token * Self::padded_tokens(elem) as u32
    }

    fn run(&self, elem: &Element) -> Duration {
        let d = self.step_time(elem);
        if self.realtime {
            std::thread::sleep(d);
        }
        d
    }
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: u64,
    pub wall: Duration,
    /// Sum over clients of accelerator-busy time.
    pub accel_busy: Duration,
    /// Wall-clock time accelerators spent waiting on input or the barrier.
    pub stall: Duration,
    pub batches_per_sec: f64,
    /// Mean fraction of each step that was padding (NLP waste metric).
    pub mean_padding_fraction: f64,
}

/// Synchronous data-parallel trainer over N client iterators.
///
/// Each client thread: fetch batch → barrier → "compute" (max over clients
/// is implicit: the barrier makes everyone wait for the slowest fetch, and
/// compute times differ only through batch shapes).
pub struct SyncTrainer {
    pub step_model: StepModel,
    pub max_steps: u64,
    pub metrics: Registry,
}

impl SyncTrainer {
    pub fn new(step_model: StepModel, max_steps: u64) -> SyncTrainer {
        SyncTrainer { step_model, max_steps, metrics: Registry::new() }
    }

    /// Run all client iterators to completion (or `max_steps`), returning
    /// the aggregate report. Blocks until done.
    pub fn run(&self, clients: Vec<Box<dyn ElemIter>>) -> DataResult<TrainReport> {
        let n = clients.len().max(1);
        let barrier = Arc::new(Barrier::new(n));
        let stop_step = Arc::new(AtomicUsize::new(usize::MAX));
        let stats = Arc::new(Mutex::new((Duration::ZERO, Duration::ZERO, 0f64, 0u64))); // (busy, stall, pad_frac_sum, steps)
        let t0 = Instant::now();

        let mut handles = Vec::new();
        for (ci, mut it) in clients.into_iter().enumerate() {
            let barrier = barrier.clone();
            let model = self.step_model.clone();
            let stats = stats.clone();
            let stop_step = stop_step.clone();
            let max_steps = self.max_steps;
            let series = self.metrics.series(&format!("trainer/client{ci}/step_time"));
            handles.push(std::thread::spawn(move || -> DataResult<()> {
                let mut step = 0u64;
                loop {
                    if step >= max_steps || step >= stop_step.load(Ordering::SeqCst) as u64 {
                        barrier.wait();
                        break;
                    }
                    let fetch_t0 = Instant::now();
                    let elem = it.next()?;
                    let fetch = fetch_t0.elapsed();
                    match elem {
                        Some(e) => {
                            // Synchronous step: all clients align here.
                            let wait_t0 = Instant::now();
                            barrier.wait();
                            let sync = wait_t0.elapsed();
                            let busy = model.run(&e);
                            let pad_frac = padding_fraction(&e);
                            series.record_at(step as f64, busy.as_secs_f64());
                            let mut st = stats.lock().unwrap();
                            st.0 += busy;
                            st.1 += fetch + sync;
                            st.2 += pad_frac;
                            st.3 += 1;
                            step += 1;
                        }
                        None => {
                            // Source exhausted: everyone stops at this step.
                            stop_step.fetch_min(step as usize, Ordering::SeqCst);
                            barrier.wait();
                            break;
                        }
                    }
                }
                Ok(())
            }));
        }
        let mut first_err = None;
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => {
                    first_err =
                        first_err.or(Some(crate::data::DataError::Other("client thread panicked".into())))
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let wall = t0.elapsed();
        let (busy, stall, pad_sum, steps) = {
            let st = stats.lock().unwrap();
            (st.0, st.1, st.2, st.3)
        };
        let per_client_steps = steps / n as u64;
        Ok(TrainReport {
            steps: per_client_steps,
            wall,
            accel_busy: busy,
            stall,
            batches_per_sec: steps as f64 / wall.as_secs_f64(),
            mean_padding_fraction: if steps > 0 { pad_sum / steps as f64 } else { 0.0 },
        })
    }
}

/// Fraction of a padded NLP batch that is padding (zeros) — the waste
/// coordinated reads exists to reduce. 0 for non-2D or non-integer
/// batches.
pub fn padding_fraction(e: &Element) -> f64 {
    let Some(t) = e.tensors.first() else { return 0.0 };
    if t.rank() != 2 {
        return 0.0;
    }
    let total = t.num_elements();
    if total == 0 {
        return 0.0;
    }
    let zeros = match t.dtype {
        DType::U32 => t.as_u32().iter().filter(|&&v| v == 0).count(),
        DType::I32 => t.as_i32().iter().filter(|&&v| v == 0).count(),
        _ => return 0.0,
    };
    zeros as f64 / total as f64
}

/// The real PJRT-backed train step for the e2e example: holds the model
/// parameters and advances them one SGD step per batch.
pub struct PjrtTrainStep {
    engine: crate::runtime::Engine,
    params: Vec<Tensor>,
    pub losses: Vec<f32>,
    lr: f32,
}

impl PjrtTrainStep {
    /// Initialize parameters via the `params_init` artifact.
    pub fn new(engine: crate::runtime::Engine, lr: f32) -> Result<PjrtTrainStep, String> {
        let params = engine.execute("params_init", vec![]).map_err(|e| e.to_string())?;
        Ok(PjrtTrainStep { engine, params, losses: Vec::new(), lr })
    }

    pub fn param_count(&self) -> usize {
        self.params.iter().map(|t| t.num_elements()).sum()
    }

    /// One SGD step on an `i32[batch, seq+1]` token batch. Returns loss.
    pub fn step(&mut self, tokens: Tensor) -> Result<f32, String> {
        let mut inputs = self.params.clone();
        inputs.push(tokens);
        inputs.push(Tensor::scalar_f32(self.lr));
        let out = self.engine.execute("train_step", inputs).map_err(|e| e.to_string())?;
        let loss = out.last().unwrap().as_f32()[0];
        self.params = out[..out.len() - 1].to_vec();
        self.losses.push(loss);
        Ok(loss)
    }

    /// Loss without updating parameters.
    pub fn eval(&self, tokens: Tensor) -> Result<f32, String> {
        let mut inputs = self.params.clone();
        inputs.push(tokens);
        let out = self.engine.execute("eval_loss", inputs).map_err(|e| e.to_string())?;
        Ok(out[0].as_f32()[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::exec::{Executor, ExecutorConfig};
    use crate::data::graph::PipelineBuilder;
    use crate::data::udf::UdfRegistry;
    use crate::storage::ObjectStore;

    fn local_iter(n: u64, batch: u32) -> Box<dyn ElemIter> {
        let ex = Executor::new(ExecutorConfig::local(
            ObjectStore::in_memory(),
            UdfRegistry::with_builtins(),
            0,
        ));
        let g = PipelineBuilder::source_range(n).batch(batch).build();
        ex.iterate(&g).unwrap()
    }

    #[test]
    fn single_client_runs_all_steps() {
        let trainer = SyncTrainer::new(StepModel::fixed(Duration::from_micros(100)), 100);
        let report = trainer.run(vec![local_iter(20, 2)]).unwrap();
        assert_eq!(report.steps, 10);
        assert!(report.batches_per_sec > 0.0);
        assert!(report.accel_busy >= Duration::from_micros(900));
    }

    #[test]
    fn max_steps_caps_run() {
        let trainer = SyncTrainer::new(StepModel::fixed(Duration::ZERO), 3);
        let report = trainer.run(vec![local_iter(100, 1)]).unwrap();
        assert_eq!(report.steps, 3);
    }

    #[test]
    fn two_clients_stay_in_lockstep() {
        let trainer = SyncTrainer::new(StepModel::fixed(Duration::from_micros(50)), 5);
        let report = trainer.run(vec![local_iter(10, 1), local_iter(10, 1)]).unwrap();
        assert_eq!(report.steps, 5);
    }

    #[test]
    fn step_model_scales_with_tokens() {
        let m = StepModel {
            base: Duration::from_millis(1),
            per_token: Duration::from_micros(10),
            realtime: false,
        };
        let small = Element::new(vec![Tensor::from_u32(vec![2, 4], &[1; 8])]);
        let big = Element::new(vec![Tensor::from_u32(vec![2, 64], &[1; 128])]);
        assert!(m.step_time(&big) > m.step_time(&small));
        assert_eq!(m.step_time(&small), Duration::from_micros(1000 + 80));
    }

    #[test]
    fn padding_fraction_counts_zeros() {
        let half = Element::new(vec![Tensor::from_u32(vec![2, 4], &[1, 1, 0, 0, 1, 1, 0, 0])]);
        assert!((padding_fraction(&half) - 0.5).abs() < 1e-9);
        let none = Element::new(vec![Tensor::from_u32(vec![1, 2], &[3, 4])]);
        assert_eq!(padding_fraction(&none), 0.0);
        let scalar = Element::new(vec![Tensor::scalar_u32(0)]);
        assert_eq!(padding_fraction(&scalar), 0.0);
    }
}
