//! Hot-path microbenchmarks (§Perf in EXPERIMENTS.md).
//!
//! Measures the real components on this machine:
//!   * CRC-32 slice-by-16 vs the scalar table loop (asserted speedup),
//!   * adaptive codec chooser vs unconditional LZ on incompressible data
//!     (asserted speedup),
//!   * wire encode/decode of a batch-sized Element,
//!   * RPC round-trip latency and streaming throughput (loopback),
//!   * pipeline executor throughput (map / parallel map / batch),
//!   * concurrent shared fetch through the sharded sliding cache,
//!   * end-to-end service GetElement throughput,
//!   * PJRT preprocess + train-step latency (if artifacts exist).
//!
//! `--smoke` shrinks iteration counts and datasets and relaxes the
//! asserted ratios for CI. Results land in
//! `out/bench_micro_hotpath.json` plus the repo-root `BENCH_hotpath.json`
//! baseline the roadmap's bench trajectory tracks.

use std::sync::Arc;
use std::time::{Duration, Instant};
use tfdatasvc::data::element::{Element, Tensor};
use tfdatasvc::data::exec::{ElemIter, Executor, ExecutorConfig};
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::metrics::write_json_file;
use tfdatasvc::orchestrator::Cell;
use tfdatasvc::rpc::{Client, Server};
use tfdatasvc::service::dispatcher::DispatcherConfig;
use tfdatasvc::service::proto::{ShardingPolicy, SharingMode};
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::storage::dataset::{generate_vision, VisionGenConfig};
use tfdatasvc::storage::ObjectStore;
use tfdatasvc::util::crc32::{crc32, crc32_scalar};
use tfdatasvc::util::json::obj;
use tfdatasvc::wire::{compress, AdaptiveCodec, CodecAction, Decode, Encode};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    for _ in 0..iters.div_ceil(10) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<44} {:>10.1} µs/op {:>12.0} op/s", per * 1e6, 1.0 / per);
    per
}

fn batch_element() -> Element {
    // A 16x32x32x3 f32 batch + labels: ~196 KiB, typical demo batch.
    Element::with_ids(
        vec![
            Tensor::from_f32(vec![16, 32, 32, 3], &vec![0.5; 16 * 32 * 32 * 3]),
            Tensor::from_u32(vec![16], &[7; 16]),
        ],
        (0..16).collect(),
    )
}

/// Deterministic high-entropy bytes (multiplicative hash) — the LZ codec
/// finds nothing to fold, which is exactly the shape the adaptive
/// chooser must learn to skip.
fn incompressible(n: usize) -> Vec<u8> {
    (0..n)
        .map(|i| ((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as u8)
        .collect()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // Iteration scaler: smoke keeps 1/5 of the reps (floor keeps the
    // adaptive codec's probe phase a small fraction of the measurement).
    let it = |n: usize| if smoke { (n / 5).max(50) } else { n };
    println!("=== micro_hotpath{} ===", if smoke { " (smoke)" } else { "" });

    // ---- crc32: slice-by-16 vs the scalar oracle ----
    // Every record frame, spill segment, and journal record pays a CRC;
    // the slice-by-16 tables must beat the byte-at-a-time loop by a
    // clear margin or the acceleration is not real.
    let crc_buf = incompressible(1 << 20);
    let mib = crc_buf.len() as f64 / (1 << 20) as f64;
    let per_fast = bench("crc32: slice-by-16 (1 MiB)", it(1000), || {
        std::hint::black_box(crc32(&crc_buf));
    });
    let per_scalar = bench("crc32: scalar table loop (1 MiB)", it(250), || {
        std::hint::black_box(crc32_scalar(&crc_buf));
    });
    assert_eq!(crc32(&crc_buf), crc32_scalar(&crc_buf), "fast path must agree with the oracle");
    let crc_speedup = per_scalar / per_fast;
    let (crc_fast_gbs, crc_scalar_gbs) =
        (mib / 1024.0 / per_fast, mib / 1024.0 / per_scalar);
    println!(
        "{:<44} {crc_fast_gbs:>7.2} GiB/s vs {crc_scalar_gbs:.2} GiB/s ({crc_speedup:.1}x)",
        "crc32: fast vs scalar"
    );
    let min_crc = if smoke { 1.5 } else { 2.0 };
    assert!(
        crc_speedup >= min_crc,
        "acceptance: slice-by-16 must be >= {min_crc}x the scalar loop (got {crc_speedup:.2}x)"
    );

    // ---- adaptive codec: observed-ratio chooser vs unconditional LZ ----
    // On incompressible payloads the chooser settles on Skip after its
    // probe budget, so the steady-state cost is a size-class lookup
    // instead of a full LZ pass — that gap is the worker's serve-path
    // saving on already-compressed or high-entropy data.
    let codec_buf = incompressible(256 << 10);
    let codec_mib = codec_buf.len() as f64 / (1 << 20) as f64;
    let per_lz = bench("codec: unconditional LZ (256 KiB random)", it(150), || {
        std::hint::black_box(compress(&codec_buf).len());
    });
    let codec = AdaptiveCodec::new();
    let per_adaptive = bench("codec: adaptive chooser (256 KiB random)", it(150), || {
        match codec.plan(codec_buf.len()) {
            CodecAction::Trial => {
                let z = compress(&codec_buf);
                codec.record_trial(codec_buf.len(), z.len());
                std::hint::black_box(z.len());
            }
            CodecAction::Compress => {
                std::hint::black_box(compress(&codec_buf).len());
            }
            CodecAction::Skip => {
                std::hint::black_box(codec_buf.len());
            }
        }
    });
    let codec_speedup = per_lz / per_adaptive;
    println!(
        "{:<44} {:>7.0} MiB/s vs {:.0} MiB/s ({codec_speedup:.0}x)",
        "codec: adaptive vs always-LZ",
        codec_mib / per_adaptive,
        codec_mib / per_lz
    );
    let min_codec = if smoke { 1.5 } else { 2.0 };
    assert!(
        codec_speedup >= min_codec,
        "acceptance: settled Skip must be >= {min_codec}x unconditional LZ on incompressible \
         data (got {codec_speedup:.2}x)"
    );

    // ---- wire ----
    let elem = batch_element();
    let bytes = elem.to_bytes();
    println!("element size on wire: {} KiB", bytes.len() / 1024);
    let per_enc = bench("wire: encode batch element", it(2000), || {
        std::hint::black_box(elem.to_bytes());
    });
    let per_dec = bench("wire: decode batch element", it(2000), || {
        std::hint::black_box(Element::from_bytes(&bytes).unwrap());
    });

    // ---- rpc ----
    let srv = Server::bind("127.0.0.1:0", |_m, p: &[u8]| Ok(p.to_vec().into())).unwrap();
    let client = Client::connect(&srv.local_addr().to_string(), Duration::from_secs(2)).unwrap();
    let per_rt = bench("rpc: 64 B round-trip (loopback)", it(2000), || {
        client.call(1, b"ping64bytes_ping64bytes_ping64bytes_ping64bytes_ping64.", Duration::from_secs(2)).unwrap();
    });
    let payload = vec![0u8; 1 << 20];
    let per = bench("rpc: 1 MiB echo (loopback)", it(300), || {
        client.call(1, &payload, Duration::from_secs(5)).unwrap();
    });
    let gbit = 2.0 * 8.0 / (per * 1e9) * 1e6 * (payload.len() as f64 / 1e6);
    println!("{:<44} {:>10.2} Gbit/s", "rpc: implied loopback throughput", gbit);

    // ---- pipeline executor ----
    let store = ObjectStore::in_memory();
    let (shards, samples) = if smoke { (2, 32) } else { (4, 64) };
    let spec = generate_vision(
        &store,
        "bench",
        &VisionGenConfig { num_shards: shards, samples_per_shard: samples, ..Default::default() },
    );
    let n_shards = spec.num_shards();
    let mk_exec = || {
        Executor::new(ExecutorConfig::local(store.clone(), UdfRegistry::with_builtins(), n_shards))
    };
    for (name, graph) in [
        ("pipeline: source+batch(16)", PipelineBuilder::source_vision(spec.clone()).batch(16).build()),
        (
            "pipeline: +normalize+augment map x1",
            PipelineBuilder::source_vision(spec.clone())
                .map("vision.normalize+vision.augment")
                .batch(16)
                .build(),
        ),
        (
            "pipeline: +normalize+augment pmap x8",
            PipelineBuilder::source_vision(spec.clone())
                .map_parallel("vision.normalize+vision.augment", 8)
                .batch(16)
                .build(),
        ),
    ] {
        let ex = mk_exec();
        let t0 = Instant::now();
        let mut total = 0usize;
        let reps = if smoke { 2 } else { 8 };
        for _ in 0..reps {
            let mut it = ex.iterate(&graph).unwrap();
            while let Ok(Some(e)) = it.next() {
                total += e.ids.len();
            }
        }
        let eps = total as f64 / t0.elapsed().as_secs_f64();
        println!("{name:<44} {eps:>10.0} samples/s");
    }

    // ---- concurrent shared fetch (sharded sliding cache) ----
    // k anonymous clients attach to one shared production and drain it
    // concurrently: with per-consumer cursor shards over the element
    // ring, independent-mode fetches from distinct sessions no longer
    // serialize on one cache mutex. Aggregate delivery rate is reported
    // against a single-client drain of the same pipeline (relaxed
    // visitation means deliveries, not elements, are the unit).
    let shared_fetch = |k: usize| -> (u64, f64) {
        let cell = Arc::new(
            Cell::new(store.clone(), UdfRegistry::with_builtins(), DispatcherConfig::default())
                .unwrap(),
        );
        cell.set_worker_config_mutator(|c| c.cache_window = 8192);
        cell.scale_to(1).unwrap();
        let rows = if smoke { 4096 } else { 16384 };
        let graph = PipelineBuilder::source_range(rows).batch(8).build();
        // Join all k first so every attach lands on a live job, then
        // drain concurrently (the fig10 sharing pattern).
        let iters: Vec<_> = (0..k)
            .map(|_| {
                ServiceClient::new(&cell.dispatcher_addr())
                    .distribute(
                        &graph,
                        ServiceClientConfig {
                            sharding: ShardingPolicy::Dynamic,
                            sharing: SharingMode::Auto,
                            ..Default::default()
                        },
                    )
                    .unwrap()
            })
            .collect();
        let t0 = Instant::now();
        let handles: Vec<_> = iters
            .into_iter()
            .map(|mut it| {
                std::thread::spawn(move || {
                    let mut n = 0u64;
                    while let Ok(Some(_)) = it.next() {
                        n += 1;
                    }
                    n
                })
            })
            .collect();
        let delivered: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        (delivered, t0.elapsed().as_secs_f64())
    };
    let (one_n, one_secs) = shared_fetch(1);
    let fan = 4usize;
    let (fan_n, fan_secs) = shared_fetch(fan);
    let (one_rate, fan_rate) = (one_n as f64 / one_secs, fan_n as f64 / fan_secs);
    println!(
        "{:<44} {fan_rate:>10.0} deliveries/s ({fan} clients) vs {one_rate:.0} (1 client)",
        "cache: concurrent shared fetch"
    );

    // ---- end-to-end service GetElement ----
    let cell = Arc::new(
        Cell::new(store.clone(), UdfRegistry::with_builtins(), DispatcherConfig::default()).unwrap(),
    );
    cell.scale_to(2).unwrap();
    let take = if smoke { 50 } else { 200 };
    let graph = PipelineBuilder::source_vision(spec).repeat(0).batch(16).take(take).build();
    let svc = ServiceClient::new(&cell.dispatcher_addr());
    let mut it2 = svc
        .distribute(&graph, ServiceClientConfig { sharding: ShardingPolicy::Off, ..Default::default() })
        .unwrap();
    let t0 = Instant::now();
    let mut batches = 0;
    let mut bytes_total = 0usize;
    while let Ok(Some(e)) = it2.next() {
        batches += 1;
        bytes_total += e.byte_len();
    }
    let dt = t0.elapsed().as_secs_f64();
    let e2e_mibs = bytes_total as f64 / dt / (1 << 20) as f64;
    println!(
        "{:<44} {:>10.0} batches/s {:>8.0} MiB/s",
        "service: e2e GetElement (2 workers)",
        batches as f64 / dt,
        e2e_mibs
    );

    // ---- PJRT (optional) ----
    if let Ok(engine) = tfdatasvc::runtime::Engine::load(tfdatasvc::runtime::default_artifacts_dir()) {
        let m = engine.manifest().clone();
        engine.warm("preprocess_vision").unwrap();
        let (b, h, c) = (m.vision_batch, m.vision_hw, m.vision_c);
        let inputs = vec![
            Tensor::from_u8(vec![b, h, h, c], vec![100; b * h * h * c]),
            Tensor::from_f32(vec![b], &vec![0.0; b]),
            Tensor::from_f32(vec![b], &vec![0.0; b]),
            Tensor::from_f32(vec![b], &vec![1.0; b]),
        ];
        bench("pjrt: preprocess_vision (Pallas fused aug)", 100, || {
            std::hint::black_box(engine.execute("preprocess_vision", inputs.clone()).unwrap());
        });
        let mut trainer = tfdatasvc::train::PjrtTrainStep::new(engine, 0.05).unwrap();
        let toks: Vec<i32> = (0..m.model_batch * (m.model_seq + 1)).map(|i| (i % 250) as i32).collect();
        let tok_t = Tensor::from_i32(vec![m.model_batch, m.model_seq + 1], &toks);
        bench("pjrt: transformer train_step (fwd+bwd+sgd)", 50, || {
            trainer.step(tok_t.clone()).unwrap();
        });
    } else {
        println!("(artifacts not built; skipping PJRT benches)");
    }

    let bench_json = obj([
        ("bench", "micro_hotpath".into()),
        ("smoke", smoke.into()),
        (
            "crc32",
            obj([
                ("fast_gib_per_sec", crc_fast_gbs.into()),
                ("scalar_gib_per_sec", crc_scalar_gbs.into()),
                ("speedup", crc_speedup.into()),
            ]),
        ),
        (
            "codec",
            obj([
                ("adaptive_mib_per_sec", (codec_mib / per_adaptive).into()),
                ("always_lz_mib_per_sec", (codec_mib / per_lz).into()),
                ("skip_speedup", codec_speedup.into()),
            ]),
        ),
        (
            "wire",
            obj([
                ("encode_us", (per_enc * 1e6).into()),
                ("decode_us", (per_dec * 1e6).into()),
            ]),
        ),
        (
            "rpc",
            obj([
                ("roundtrip_us", (per_rt * 1e6).into()),
                ("loopback_gbit_per_sec", gbit.into()),
            ]),
        ),
        (
            "shared_fetch",
            obj([
                ("clients", (fan as u64).into()),
                ("aggregate_deliveries_per_sec", fan_rate.into()),
                ("single_client_deliveries_per_sec", one_rate.into()),
            ]),
        ),
        ("e2e_mib_per_sec", e2e_mibs.into()),
    ]);
    write_json_file("out/bench_micro_hotpath.json", &bench_json).unwrap();
    // Repo-root mirror under the stable name the roadmap's bench
    // trajectory tracks (CI regenerates and uploads it every run).
    write_json_file("BENCH_hotpath.json", &bench_json).unwrap();
    println!("micro_hotpath OK -> out/bench_micro_hotpath.json + BENCH_hotpath.json");
}
