//! Pipelined coordinated reads (§3.6): round-lease prefetch on vs off
//! under skewed element sizes — the paper's straggler scenario.
//!
//! The trainer spends ~T per step on compute; every round costs F on the
//! wire (materialize is already overlapped by the worker's multi-round
//! buffer; F is transfer + decode, with periodic stragglers several
//! times larger than the median, travelling as continuation frames
//! against a small negotiated frame budget). Lock-step pays `T + F` per
//! step; the prefetching client pays `max(T, F)` — the §3.6 software
//! pipeline applied across the wire.
//!
//! Acceptance (full mode): prefetch-on >= 1.5x steps/sec and a lower
//! p99 round latency than prefetch-off. A second section compares the
//! single-thread pipelined engine against **multi-owner concurrent
//! fetch** on a 3-worker topology (one in-flight round per distinct
//! owner): >= 1.2x steps/sec required, smoke included. `--smoke`
//! shrinks the epochs and relaxes the prefetch ratio for shared CI
//! boxes. Results are also emitted machine-readable to
//! `out/bench_coordinated_rounds.json`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use tfdatasvc::data::element::{DType, Tensor};
use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::data::Element;
use tfdatasvc::metrics::write_json_file;
use tfdatasvc::service::dispatcher::{Dispatcher, DispatcherConfig};
use tfdatasvc::service::proto::{ProcessingMode, ShardingPolicy};
use tfdatasvc::service::worker::{Worker, WorkerConfig, MIN_STREAM_FRAME_LEN};
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::storage::ObjectStore;
use tfdatasvc::util::hist::Samples;
use tfdatasvc::util::json::obj;

/// Median element ~512 KiB; every 4th a ~4 MiB straggler. Against a
/// 128 KiB negotiated frame budget both travel as continuation frames,
/// so the fetch cost F is dominated by chunk RPC round-trips and skews
/// hard at p99.
const SMALL_BYTES: usize = 512 << 10;
const BIG_BYTES: usize = 4 << 20;

struct RunStats {
    steps: u64,
    secs: f64,
    mean_ms: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    prefetched: u64,
}

fn run(
    dispatcher_addr: &str,
    graph: &tfdatasvc::data::GraphDef,
    depth: u32,
    concurrent: bool,
    train_step: Duration,
) -> RunStats {
    let client = ServiceClient::new(dispatcher_addr);
    let mut it = client
        .distribute(
            graph,
            ServiceClientConfig {
                sharding: ShardingPolicy::Off,
                mode: ProcessingMode::Coordinated,
                num_consumers: 1,
                consumer_index: 0,
                max_frame_len: MIN_STREAM_FRAME_LEN as u64,
                round_prefetch_depth: depth,
                concurrent_round_fetch: concurrent,
                ..Default::default()
            },
        )
        .unwrap();
    let mut lat = Samples::new();
    let t0 = Instant::now();
    let mut steps = 0u64;
    loop {
        let f0 = Instant::now();
        match it.next() {
            Ok(Some(e)) => {
                lat.push(f0.elapsed().as_secs_f64() * 1e3);
                std::hint::black_box(&e);
                steps += 1;
                // "Train" on the round: spin for the step budget (spin,
                // not sleep — immune to timer quantization on CI boxes).
                let s0 = Instant::now();
                while s0.elapsed() < train_step {
                    std::hint::black_box(steps);
                }
            }
            Ok(None) => break,
            Err(e) => panic!("round fetch failed: {e}"),
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let prefetched = client.metrics().counter("client/rounds_prefetched").get();
    it.release();
    RunStats {
        steps,
        secs,
        mean_ms: lat.mean(),
        p50_ms: lat.percentile(50.0),
        p95_ms: lat.percentile(95.0),
        p99_ms: lat.percentile(99.0),
        prefetched,
    }
}

/// Skewed element sizes: the straggler scenario coordinated reads exist
/// for (§3.6) — every 4th element ~8x the median.
fn skewed_udfs() -> UdfRegistry {
    let udfs = UdfRegistry::with_builtins();
    udfs.register_fn("bench.skew", move |e| {
        let n = if e.ids[0] % 4 == 3 { BIG_BYTES } else { SMALL_BYTES };
        Ok(Element::with_ids(
            vec![Tensor::new(DType::U8, vec![n], vec![(e.ids[0] % 251) as u8; n])],
            e.ids.clone(),
        ))
    });
    udfs
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let rounds: u64 = if smoke { 96 } else { 384 };

    let store = ObjectStore::in_memory();
    let d = Dispatcher::start("127.0.0.1:0", DispatcherConfig::default()).unwrap();
    let _w =
        Worker::start("127.0.0.1:0", &d.addr(), WorkerConfig::new(store, skewed_udfs())).unwrap();
    let graph = Arc::new(PipelineBuilder::source_range(rounds).map("bench.skew").build());
    let calib_graph = PipelineBuilder::source_range(32).map("bench.skew").build();

    // Self-calibrate the trainer's step budget to the *measured* mean
    // fetch cost on this machine: the software pipeline's win is largest
    // (2x ideal) when compute and fetch are balanced, and calibrating
    // keeps the acceptance ratio meaningful on fast and slow boxes
    // alike.
    let probe = run(&d.addr(), &calib_graph, 0, false, Duration::ZERO);
    let train_step = Duration::from_secs_f64(
        (probe.mean_ms / 1e3).clamp(0.000_3, 0.02),
    );
    println!(
        "=== coordinated_rounds: round-lease prefetch on vs off ({} rounds{}, fetch ~{:.2} ms, \
         train step {:.2} ms) ===",
        rounds,
        if smoke { ", smoke" } else { "" },
        probe.mean_ms,
        train_step.as_secs_f64() * 1e3
    );
    println!(
        "{:<14} {:>8} {:>10} {:>9} {:>9} {:>9} {:>11}",
        "mode", "steps", "steps/s", "p50 ms", "p95 ms", "p99 ms", "prefetched"
    );
    let report = |name: &str, s: &RunStats| {
        println!(
            "{:<14} {:>8} {:>10.1} {:>9.2} {:>9.2} {:>9.2} {:>11}",
            name,
            s.steps,
            s.steps as f64 / s.secs,
            s.p50_ms,
            s.p95_ms,
            s.p99_ms,
            s.prefetched
        );
    };
    // Off first (cold caches penalize the baseline, not the candidate —
    // conservative for the assertion below). Each mode drains one full
    // epoch of the same pipeline. Both prefetch modes here use the
    // single-thread engine: the multi-owner comparison below isolates
    // concurrency on a 3-worker topology.
    let off = run(&d.addr(), &graph, 0, false, train_step);
    report("prefetch-off", &off);
    let on = run(&d.addr(), &graph, 2, false, train_step);
    report("prefetch-on", &on);

    assert_eq!(on.steps, off.steps, "both modes must deliver the same round count");
    assert_eq!(off.prefetched, 0, "depth 0 is lock-step");
    assert!(on.prefetched > 0, "depth 2 really prefetched");

    let speedup = (on.steps as f64 / on.secs) / (off.steps as f64 / off.secs);
    println!(
        "prefetch speedup: {speedup:.2}x steps/sec, p99 round latency {:.2} ms -> {:.2} ms",
        off.p99_ms, on.p99_ms
    );

    // --- Multi-owner concurrent fetch on a 3-worker topology (§3.6
    // across owners). The single-thread pipelined engine serializes wire
    // transfers even with rounds prefetched; the multi-owner engine
    // keeps one round in flight per distinct owner, so the round cadence
    // approaches fetch/3. Both engines run depth 3 over the same
    // cluster; the trainer step is calibrated to a third of the measured
    // fetch cost (the fetch-dominated regime the concurrency targets).
    let rounds3: u64 = if smoke { 40 } else { 128 };
    let d3 = Dispatcher::start("127.0.0.1:0", DispatcherConfig::default()).unwrap();
    let store3 = ObjectStore::in_memory();
    let _workers3: Vec<Worker> = (0..3)
        .map(|_| {
            Worker::start(
                "127.0.0.1:0",
                &d3.addr(),
                WorkerConfig::new(store3.clone(), skewed_udfs()),
            )
            .unwrap()
        })
        .collect();
    let graph3 = PipelineBuilder::source_range(rounds3).map("bench.skew").build();
    let calib3 = PipelineBuilder::source_range(12).map("bench.skew").build();
    let probe3 = run(&d3.addr(), &calib3, 0, false, Duration::ZERO);
    let train_step3 =
        Duration::from_secs_f64((probe3.mean_ms / 1e3 / 3.0).clamp(0.000_1, 0.01));
    println!(
        "=== multi-owner concurrent fetch: 3 workers, depth 3 (fetch ~{:.2} ms, train step \
         {:.2} ms) ===",
        probe3.mean_ms,
        train_step3.as_secs_f64() * 1e3
    );
    let single = run(&d3.addr(), &graph3, 3, false, train_step3);
    report("single-thread", &single);
    let multi = run(&d3.addr(), &graph3, 3, true, train_step3);
    report("multi-owner", &multi);
    assert_eq!(
        multi.steps, single.steps,
        "both engines must deliver the same round count"
    );
    let mo_speedup =
        (multi.steps as f64 / multi.secs) / (single.steps as f64 / single.secs);
    println!("multi-owner speedup: {mo_speedup:.2}x steps/sec over the single-thread engine");

    write_json_file(
        "out/bench_coordinated_rounds.json",
        &obj([
            ("bench", "coordinated_rounds".into()),
            ("smoke", smoke.into()),
            ("rounds", rounds.into()),
            ("fetch_mean_ms", probe.mean_ms.into()),
            ("train_step_ms", (train_step.as_secs_f64() * 1e3).into()),
            (
                "prefetch_off",
                obj([
                    ("steps_per_sec", (off.steps as f64 / off.secs).into()),
                    ("p50_ms", off.p50_ms.into()),
                    ("p95_ms", off.p95_ms.into()),
                    ("p99_ms", off.p99_ms.into()),
                ]),
            ),
            (
                "prefetch_on",
                obj([
                    ("steps_per_sec", (on.steps as f64 / on.secs).into()),
                    ("p50_ms", on.p50_ms.into()),
                    ("p95_ms", on.p95_ms.into()),
                    ("p99_ms", on.p99_ms.into()),
                    ("rounds_prefetched", on.prefetched.into()),
                ]),
            ),
            ("speedup", speedup.into()),
            (
                "multi_owner",
                obj([
                    ("workers", 3.0.into()),
                    ("depth", 3.0.into()),
                    ("single_steps_per_sec", (single.steps as f64 / single.secs).into()),
                    ("multi_steps_per_sec", (multi.steps as f64 / multi.secs).into()),
                    ("single_p99_ms", single.p99_ms.into()),
                    ("multi_p99_ms", multi.p99_ms.into()),
                    ("speedup", mo_speedup.into()),
                ]),
            ),
        ]),
    )
    .unwrap();

    // Acceptance: the pipeline must beat lock-step decisively under skew
    // in full mode; smoke (CI) only guards against gross regressions —
    // shared runners are too noisy for the full bar.
    let min_speedup = if smoke { 1.1 } else { 1.5 };
    assert!(
        speedup >= min_speedup,
        "acceptance: prefetch-on must sustain >= {min_speedup}x steps/sec vs lock-step \
         (got {speedup:.2}x)"
    );
    if !smoke {
        assert!(
            on.p99_ms < off.p99_ms,
            "acceptance: prefetch must cut p99 round latency ({:.2} ms vs {:.2} ms)",
            on.p99_ms,
            off.p99_ms
        );
    }
    // Acceptance (smoke included): multi-owner concurrent fetch must
    // sustain >= 1.2x steps/sec over the single-thread engine on the
    // 3-worker topology (theoretical ceiling ~3x in this fetch-bound
    // regime, so 1.2x leaves headroom for noisy CI boxes).
    assert!(
        mo_speedup >= 1.2,
        "acceptance: multi-owner engine must sustain >= 1.2x steps/sec vs single-thread \
         (got {mo_speedup:.2}x)"
    );
    println!("coordinated_rounds OK -> out/bench_coordinated_rounds.json");
}
