//! Fig. 11: coordinated-reads speedups for the NLP models.
//!
//! Paper rows: M5 1.62x, M6 1.53x, M7 3.5x, M8 2.15x (avg 2.2x), from
//! dynamic-sequence-length training with bucket boundaries at multiples
//! of 64 (M5/M7) or 128 (M6/M8).

use tfdatasvc::metrics::write_csv_rows;
use tfdatasvc::sim::coord::{simulate_coordinated_reads, CoordSimConfig};
use tfdatasvc::sim::models::model;

fn main() {
    println!("=== Fig 11: coordinated-reads speedup (NLP models) ===");
    println!(
        "{:<6} {:>6} {:>8} {:>9} {:>9} {:>10} {:>8}",
        "model", "accel", "bucket", "pad un%", "pad co%", "speedup", "paper"
    );
    let mut rows = Vec::new();
    let mut total = 0.0;
    for name in ["M5", "M6", "M7", "M8"] {
        let m = model(name);
        let r = simulate_coordinated_reads(m, &CoordSimConfig::default());
        println!(
            "{:<6} {:>6} {:>8} {:>8.1} {:>8.1} {:>9.2}x {:>7.2}x",
            name,
            m.accelerators,
            m.bucket_width,
            r.uncoordinated_padding_fraction * 100.0,
            r.coordinated_padding_fraction * 100.0,
            r.speedup,
            m.paper_speedup
        );
        let rel = (r.speedup - m.paper_speedup).abs() / m.paper_speedup;
        assert!(rel < 0.3, "{name}: {:.2} vs paper {:.2}", r.speedup, m.paper_speedup);
        rows.push(vec![
            name.to_string(),
            format!("{:.3}", r.speedup),
            format!("{:.3}", m.paper_speedup),
            format!("{:.3}", r.uncoordinated_padding_fraction),
            format!("{:.3}", r.coordinated_padding_fraction),
        ]);
        total += r.speedup;
    }
    let avg = total / 4.0;
    println!("average speedup: {avg:.2}x (paper: 2.2x)");
    assert!((avg - 2.2).abs() < 0.5);

    // Ablation the paper implies: finer buckets help more.
    let mut m = model("M7").clone();
    let fine = simulate_coordinated_reads(&m, &CoordSimConfig::default()).speedup;
    m.bucket_width = 256;
    let coarse = simulate_coordinated_reads(&m, &CoordSimConfig::default()).speedup;
    println!("ablation (M7): bucket 64 -> {fine:.2}x, bucket 256 -> {coarse:.2}x");
    assert!(fine > coarse, "finer buckets must help more");

    write_csv_rows("out/fig11.csv", "model,speedup,paper_speedup,pad_uncoord,pad_coord", &rows)
        .unwrap();
    println!("fig11 OK -> out/fig11.csv");
}
