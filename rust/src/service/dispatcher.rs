//! The dispatcher: tf.data service's metadata plane (§3.1).
//!
//! Tracks registered datasets, workers, clients, and jobs; assigns
//! dataset-processing tasks to workers; distributes dynamic splits; and
//! notifies clients of the current worker set. The dispatcher never
//! touches element data — all bytes flow worker → client.
//!
//! Fault tolerance (§3.4): every state change is journaled before being
//! acknowledged; [`Dispatcher::restore`] replays the journal. Worker
//! liveness is heartbeat-based: a worker silent for `worker_timeout` is
//! declared failed and its in-flight splits are recorded lost
//! (at-most-once visitation).

use super::journal::{
    DispatcherSnapshot, Journal, JournalRecord, SnapshotJob, SnapshotNamedJob, SnapshotWorker,
};
use super::proto::*;
use super::sharding::{static_assignment, SplitTracker};
use super::spill::{data_key, manifest_key, merge_manifests, partition_manifest, SpillManifest};
use super::{ServiceError, ServiceResult};
use crate::data::graph::GraphDef;
use crate::metrics::Registry;
use crate::rpc::{RespBody, Server};
use crate::storage::ObjectStore;
use crate::wire::{Decode, Encode};
use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Dispatcher tuning knobs.
#[derive(Clone)]
pub struct DispatcherConfig {
    /// Write-ahead journal path; `None` = in-memory only (tests).
    pub journal_path: Option<PathBuf>,
    /// A worker silent this long is declared failed.
    pub worker_timeout: Duration,
    /// Shuffle seed for dynamic split handout.
    pub split_seed: u64,
    /// A revived round-lease owner must stay alive this long before its
    /// home residues are re-balanced back from the survivors that
    /// adopted them (§3.6): hysteresis, so a flapping worker cannot
    /// thrash leases on every heartbeat it manages to land.
    pub revival_hysteresis: Duration,
    /// Compaction trigger: once the live journal suffix exceeds this many
    /// bytes, the next `tick()` cuts a [`DispatcherSnapshot`] checkpoint
    /// and swaps to a fresh suffix — off the RPC hot path. 0 disables
    /// automatic compaction (checkpoints can still be cut via
    /// [`Dispatcher::compact_now`]).
    pub journal_compact_bytes: u64,
    /// Admission budget: the maximum unfinished jobs the dispatcher will
    /// track. Past it, `GetOrCreateJob` requests that would *create* a
    /// job are shed with a retryable [`ServiceError::Overloaded`]
    /// (attaches to existing jobs stay admitted — they add a cursor, not
    /// a production). 0 disables admission control.
    pub admission_max_jobs: usize,
    /// Retry hint handed to shed clients (`Overloaded::retry_after_ms`);
    /// the service client backs off this long (jittered) before
    /// retrying.
    pub admission_retry_ms: u64,
    /// Object store for journal-driven spill-snapshot GC: when a newer
    /// epoch snapshot commits for a fingerprint, the superseded
    /// snapshot's `spill/job-{id}/*` objects are deleted here. `None`
    /// disables GC (superseded data then lives until external cleanup).
    pub store: Option<Arc<ObjectStore>>,
}

impl Default for DispatcherConfig {
    fn default() -> Self {
        DispatcherConfig {
            journal_path: None,
            worker_timeout: Duration::from_secs(10),
            split_seed: 0x5317_d15b,
            revival_hysteresis: Duration::from_millis(500),
            journal_compact_bytes: 4 << 20,
            admission_max_jobs: 4096,
            admission_retry_ms: 25,
            store: None,
        }
    }
}

// Hand-written: `ObjectStore` holds live net/region state with no Debug.
impl std::fmt::Debug for DispatcherConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DispatcherConfig")
            .field("journal_path", &self.journal_path)
            .field("worker_timeout", &self.worker_timeout)
            .field("split_seed", &self.split_seed)
            .field("revival_hysteresis", &self.revival_hysteresis)
            .field("journal_compact_bytes", &self.journal_compact_bytes)
            .field("admission_max_jobs", &self.admission_max_jobs)
            .field("admission_retry_ms", &self.admission_retry_ms)
            .field("store", &self.store.as_ref().map(|_| "ObjectStore"))
            .finish()
    }
}

#[derive(Debug)]
struct WorkerInfo {
    addr: String,
    last_heartbeat: Instant,
    /// Tasks created while the worker wasn't heartbeating, delivered on
    /// its next heartbeat.
    pending_tasks: Vec<TaskDef>,
    /// Consumers that attached to (resp. released from) one of this
    /// worker's jobs since its last heartbeat: the worker registers /
    /// drops the matching multi-consumer cache cursors (§3.5). (Also
    /// pushed synchronously via UPDATE_CONSUMERS; this queue is the
    /// reliable fallback.)
    pending_attach: Vec<ConsumerUpdate>,
    pending_detach: Vec<ConsumerUpdate>,
    /// Round-lease updates (§3.6) for this worker's coordinated tasks,
    /// delivered on its next heartbeat.
    pending_rounds: Vec<RoundAssignment>,
    /// Membership-epoch schedules (elastic consumer width) queued for
    /// this worker's next heartbeat. Each entry carries a job's *full*
    /// schedule, so duplicate delivery is idempotent.
    pending_widths: Vec<ConsumerSetUpdate>,
    /// Task (job) ids this worker should currently be running.
    assigned: HashSet<u64>,
    alive: bool,
    /// When the worker last transitioned dead -> alive (or registered).
    /// Revival re-balance waits out `revival_hysteresis` from here before
    /// handing home residues back, so a flapping worker cannot thrash
    /// round leases.
    alive_since: Instant,
    /// Heartbeat/registration evidence from the worker's *current*
    /// incarnation. Journal-replayed workers start unconfirmed: they are
    /// optimistically alive (grace before failure detection) but must
    /// not *gain* leases via revival re-balance until they actually
    /// heartbeat — otherwise a worker that died during the dispatcher's
    /// outage would be handed its home residues back and every consumer
    /// would stall on them until `worker_timeout` re-declares it dead.
    confirmed: bool,
    /// The worker is in the two-phase graceful-drain state (journaled as
    /// `WorkerDrainChanged`): no new consumers are routed to it, its
    /// round residues are being handed off via revoke-ack-grant, and it
    /// cannot gain leases. It keeps serving what it still owns until
    /// each handoff's ack lands, so the drain is stall-free.
    draining: bool,
    /// The draining worker reported (via heartbeat) that it has applied
    /// every revocation and flushed its pending spill buffers: nothing
    /// a removal would lose remains on it. Gate three of
    /// [`Dispatcher::drain_complete`].
    drain_ready: bool,
    /// Phase-one revocations queued for (and re-delivered on) this
    /// worker's heartbeats until it acks them. The lease table keeps
    /// pointing at this worker while an entry is outstanding — the
    /// gainer's grant activates only on the ack, so loser and gainer
    /// never co-hold a residue.
    pending_revocations: Vec<LeaseRevoke>,
    /// Last heartbeat-reported CPU utilization in thousandths
    /// (autoscaler input; also the least-loaded scale-down victim pick).
    last_cpu_milli: u32,
}

impl WorkerInfo {
    fn new(addr: String, last_heartbeat: Instant, alive: bool, assigned: HashSet<u64>) -> WorkerInfo {
        WorkerInfo {
            addr,
            last_heartbeat,
            pending_tasks: Vec::new(),
            pending_attach: Vec::new(),
            pending_detach: Vec::new(),
            pending_rounds: Vec::new(),
            pending_widths: Vec::new(),
            assigned,
            alive,
            alive_since: last_heartbeat,
            confirmed: true,
            draining: false,
            drain_ready: false,
            pending_revocations: Vec::new(),
            last_cpu_milli: 0,
        }
    }
}

/// One in-flight two-phase lease handoff: residue `residue` moves from
/// live owner `loser` to `gainer`, but the lease table keeps pointing at
/// the loser until its revoke ack arrives. Soft state (not journaled):
/// a dispatcher restart drops it and the next `tick()` re-plans the same
/// handoff idempotently from the (journaled) drain flags and lease table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct PendingHandoff {
    residue: u32,
    loser: u64,
    gainer: u64,
    /// True when this is a revival re-balance returning the residue to
    /// its home owner (counts `dispatcher/round_leases_rebalanced` on
    /// completion); false for drain-driven moves.
    home: bool,
}

#[derive(Debug)]
struct JobState {
    dataset_id: u64,
    job_name: String,
    sharding: ShardingPolicy,
    mode: ProcessingMode,
    num_consumers: u32,
    /// Whether later `sharing: auto` requests may attach to this job.
    sharing: SharingMode,
    tracker: Option<Arc<SplitTracker>>,
    clients: HashSet<u64>,
    finished: bool,
    /// Worker ordering for coordinated reads, fixed at creation.
    worker_order: Vec<u64>,
    /// Coordinated reads: current round-lease holder per residue
    /// (`round % num_workers` -> worker id). Starts as `worker_order`;
    /// [`Dispatcher::tick`] reassigns a failed owner's residues to
    /// survivors. The lease is renewed implicitly by worker heartbeats
    /// (`worker_timeout` is the lease duration).
    residue_owners: Vec<u64>,
    /// Coordinated reads: each consumer **slot**'s last-reported
    /// `next_round` plus when it reported. Keyed by `consumer_index`,
    /// not client id — the slot is the durable identity, so a consumer
    /// replacement (new client id, same slot) inherits its
    /// predecessor's progress for the fast-forward. Progress reports
    /// are leases like worker heartbeats: `tick()` prunes entries
    /// silent past `worker_timeout`, so a crashed consumer cannot pin
    /// the job floor forever.
    client_rounds: HashMap<u32, (u64, Instant)>,
    /// Two-phase lease handoffs in flight for this job (drain or
    /// live-to-live revival re-balance). While a residue has an entry
    /// here, neither mover re-plans it; the entry resolves on the
    /// loser's revoke ack (flip + grant) or cancels if the loser dies
    /// (failure reassignment then moves the residue — a dead loser
    /// cannot co-hold).
    pending_handoffs: Vec<PendingHandoff>,
    /// Per-client input-stall fractions (thousandths) from client
    /// heartbeats, with report times. Pruned like `client_rounds`;
    /// aggregated into the autoscaler's client-starvation signal.
    client_stalls: HashMap<u64, (u32, Instant)>,
    /// Membership-epoch schedule (elastic consumer width): epoch 0 is
    /// the creation-time width at barrier 0; `set_job_consumers`
    /// appends one entry per width change. Never empty; barriers are
    /// monotone. `num_consumers` above always mirrors the last entry.
    width_epochs: Vec<WidthEpoch>,
    /// Complete per-worker spill manifests reported via heartbeat
    /// (worker id -> manifest). Once every worker in `worker_order` has
    /// reported, the merged snapshot commits.
    spill_manifests: HashMap<u64, SpillManifest>,
    /// This job's epoch has been committed as a fingerprint-keyed
    /// snapshot — further manifest reports are acked without re-merging.
    snapshot_committed: bool,
    /// The job was created in snapshot-serve mode: its tasks carry a
    /// stored-manifest slice and stream the committed epoch instead of
    /// producing.
    snapshot_serve: bool,
}

impl JobState {
    /// Materialization floor for lease moves: the minimum round any
    /// reporting consumer slot still needs (0 before anyone has
    /// reported — a slot that has not reported yet may still need
    /// round 0, and an unreported fresh slot reports the `u64::MAX`
    /// sentinel, never 0, so it cannot be overshot for longer than its
    /// first real heartbeat).
    fn floor(&self) -> u64 {
        self.client_rounds.values().map(|&(r, _)| r).min().unwrap_or(0)
    }
}

#[derive(Default)]
struct Meta {
    datasets: HashMap<u64, GraphDef>,
    workers: HashMap<u64, WorkerInfo>,
    jobs: HashMap<u64, JobState>,
    /// (dataset_id, job_name) -> job_id for named (shared) jobs.
    named_jobs: HashMap<(u64, String), u64>,
    /// Committed snapshots, keyed by pipeline fingerprint (= dataset id).
    /// One (latest-epoch) snapshot per fingerprint: a re-submitted
    /// identical pipeline with `sharing: auto` attaches here.
    snapshots: HashMap<u64, SpillManifest>,
    next_worker_id: u64,
    next_job_id: u64,
    next_client_id: u64,
}

struct State {
    cfg: DispatcherConfig,
    journal: Option<Journal>,
    meta: Mutex<Meta>,
    metrics: Registry,
    /// Connection pool for dispatcher -> worker pushes (UPDATE_CONSUMERS).
    /// The dispatcher stays off the data path — these carry metadata only.
    pool: crate::rpc::Pool,
}

/// A running dispatcher (RPC server + state).
pub struct Dispatcher {
    state: Arc<State>,
    server: Server,
}

use super::graph_num_shards;

impl Dispatcher {
    /// Start a dispatcher on `addr` (port 0 = ephemeral), restoring from
    /// the newest valid journal snapshot + suffix if one is configured
    /// and present. Restore is corruption-tolerant ([`Journal::restore`]
    /// walks the fallback ladder); degraded steps surface as
    /// `dispatcher/restore_fallbacks`.
    pub fn start(addr: &str, cfg: DispatcherConfig) -> ServiceResult<Dispatcher> {
        let mut meta = Meta { next_worker_id: 1, next_job_id: 1, next_client_id: 1, ..Default::default() };
        let mut replayed = 0u64;
        let mut fallbacks = 0u64;
        let mut gc_replays: Vec<u64> = Vec::new();
        if let Some(p) = &cfg.journal_path {
            // Restore *before* opening the writer: `Journal::open` repairs
            // (truncates) a corrupt suffix tail, and restore must see —
            // and count — the corruption first.
            let outcome = Journal::restore(p).map_err(|e| ServiceError::Journal(e.to_string()))?;
            replayed = outcome.records.len() as u64;
            fallbacks = outcome.fallbacks;
            if let Some(snap) = outcome.snapshot {
                Self::apply_snapshot(&mut meta, snap, cfg.split_seed);
            }
            gc_replays = Self::apply_replay(&mut meta, outcome.records, cfg.split_seed);
        }
        // Replayed GC records re-issue their store deletes: the delete is
        // idempotent, so a crash landed between the append and the
        // deletes cannot leak the superseded snapshot's objects.
        if let Some(store) = &cfg.store {
            for &job_id in &gc_replays {
                store.delete(&data_key(job_id));
                store.delete(&manifest_key(job_id));
            }
        }
        let journal = match &cfg.journal_path {
            Some(p) => Some(Journal::open(p).map_err(|e| ServiceError::Journal(e.to_string()))?),
            None => None,
        };
        let state = Arc::new(State {
            cfg,
            journal,
            meta: Mutex::new(meta),
            metrics: Registry::new(),
            pool: crate::rpc::Pool::with_defaults(),
        });
        // Restore ran before the registry existed; publish its stats now.
        state.metrics.counter("dispatcher/restore_records_replayed").add(replayed);
        state.metrics.counter("dispatcher/restore_fallbacks").add(fallbacks);

        let s2 = state.clone();
        let server = Server::bind(addr, move |method: u16, payload: &[u8]| {
            handle(&s2, method, payload).map(RespBody::from).map_err(|e| e.to_string())
        })
        .map_err(|e| ServiceError::Other(format!("bind: {e}")))?;

        Ok(Dispatcher { state, server })
    }

    /// Load a checkpoint into `meta` — the fast path of restore. Soft
    /// state (client progress, in-flight handoffs, partial spill
    /// manifests, pending delivery queues) is absent from snapshots by
    /// design and rebuilt from post-restart heartbeats, exactly as
    /// full-journal replay rebuilds it. Workers restore the same way
    /// `RegisterWorker` replays: optimistically alive with one
    /// `worker_timeout` of grace, unconfirmed until they heartbeat.
    fn apply_snapshot(meta: &mut Meta, snap: DispatcherSnapshot, split_seed: u64) {
        for (dataset_id, graph) in snap.datasets {
            meta.datasets.insert(dataset_id, graph);
        }
        for sj in snap.jobs {
            let shards = meta.datasets.get(&sj.dataset_id).map(graph_num_shards).unwrap_or(1);
            let tracker = matches!(sj.sharding, ShardingPolicy::Dynamic)
                .then(|| Arc::new(SplitTracker::new(shards, split_seed ^ sj.job_id)));
            meta.jobs.insert(
                sj.job_id,
                JobState {
                    dataset_id: sj.dataset_id,
                    job_name: sj.job_name,
                    sharding: sj.sharding,
                    mode: sj.mode,
                    num_consumers: sj.num_consumers,
                    sharing: sj.sharing,
                    tracker,
                    clients: sj.clients.into_iter().collect(),
                    finished: sj.finished,
                    worker_order: sj.worker_order,
                    residue_owners: sj.residue_owners,
                    client_rounds: HashMap::new(),
                    pending_handoffs: Vec::new(),
                    client_stalls: HashMap::new(),
                    width_epochs: sj.width_epochs,
                    spill_manifests: HashMap::new(),
                    snapshot_committed: sj.snapshot_committed,
                    snapshot_serve: sj.snapshot_serve,
                },
            );
        }
        for nj in snap.named_jobs {
            meta.named_jobs.insert((nj.dataset_id, nj.job_name), nj.job_id);
        }
        for sw in snap.workers {
            let mut wi = WorkerInfo::new(sw.addr, Instant::now(), true, HashSet::new());
            wi.confirmed = false;
            wi.draining = sw.draining;
            meta.workers.insert(sw.worker_id, wi);
        }
        for (fingerprint, manifest) in snap.spill_snapshots {
            meta.snapshots.insert(fingerprint, manifest);
        }
        meta.next_worker_id = meta.next_worker_id.max(snap.next_worker_id);
        meta.next_job_id = meta.next_job_id.max(snap.next_job_id);
        meta.next_client_id = meta.next_client_id.max(snap.next_client_id);
    }

    /// Replay journal records over `meta` (either from genesis or on top
    /// of a restored snapshot — replay is deterministic and every record
    /// applies idempotently, so both paths converge). Returns the job
    /// ids of replayed [`JournalRecord::SpillSnapshotGced`] records,
    /// whose store deletes the caller re-issues.
    fn apply_replay(meta: &mut Meta, records: Vec<JournalRecord>, split_seed: u64) -> Vec<u64> {
        let mut gced = Vec::new();
        for rec in records {
            match rec {
                JournalRecord::RegisterDataset { dataset_id, graph } => {
                    meta.datasets.insert(dataset_id, graph);
                }
                JournalRecord::CreateJob {
                    job_id,
                    dataset_id,
                    job_name,
                    sharding,
                    mode,
                    num_consumers,
                    sharing,
                    worker_order,
                    snapshot,
                } => {
                    let shards = meta.datasets.get(&dataset_id).map(graph_num_shards).unwrap_or(1);
                    let tracker = matches!(sharding, ShardingPolicy::Dynamic)
                        .then(|| Arc::new(SplitTracker::new(shards, split_seed ^ job_id)));
                    if !job_name.is_empty() {
                        meta.named_jobs.insert((dataset_id, job_name.clone()), job_id);
                    }
                    meta.jobs.insert(
                        job_id,
                        JobState {
                            dataset_id,
                            job_name,
                            sharding,
                            mode,
                            num_consumers,
                            sharing,
                            tracker,
                            clients: HashSet::new(),
                            finished: false,
                            // The replayed worker order is the lease-table
                            // baseline; later RoundLeaseChanged records
                            // overwrite `residue_owners` last-writer-wins.
                            residue_owners: worker_order.clone(),
                            worker_order,
                            client_rounds: HashMap::new(),
                            pending_handoffs: Vec::new(),
                            client_stalls: HashMap::new(),
                            width_epochs: vec![WidthEpoch {
                                epoch: 0,
                                barrier_round: 0,
                                num_consumers,
                            }],
                            spill_manifests: HashMap::new(),
                            snapshot_committed: false,
                            snapshot_serve: snapshot,
                        },
                    );
                    meta.next_job_id = meta.next_job_id.max(job_id + 1);
                }
                JournalRecord::RegisterWorker { worker_id, addr } => {
                    // Restored *optimistically*: a dispatcher restart does
                    // not kill workers, so they keep their round leases
                    // and get one `worker_timeout` of grace to
                    // re-heartbeat. `tick()` then declares the truly-dead
                    // ones and reassigns their residues — without the
                    // grace-then-timeout, a worker that died during the
                    // outage would never transition alive -> dead and its
                    // residues would stay stranded (the restart ×
                    // worker-crash cell of the failure matrix). Restored
                    // workers are *unconfirmed* until their first
                    // heartbeat: they keep what they hold but cannot gain
                    // leases via revival re-balance.
                    let mut wi = WorkerInfo::new(addr, Instant::now(), true, HashSet::new());
                    wi.confirmed = false;
                    meta.workers.insert(worker_id, wi);
                    meta.next_worker_id = meta.next_worker_id.max(worker_id + 1);
                }
                JournalRecord::ClientJoined { job_id, client_id } => {
                    if let Some(j) = meta.jobs.get_mut(&job_id) {
                        j.clients.insert(client_id);
                    }
                    meta.next_client_id = meta.next_client_id.max(client_id + 1);
                }
                JournalRecord::ClientReleased { job_id, client_id } => {
                    if let Some(j) = meta.jobs.get_mut(&job_id) {
                        j.clients.remove(&client_id);
                    }
                }
                JournalRecord::JobFinished { job_id } => {
                    if let Some(j) = meta.jobs.get_mut(&job_id) {
                        j.finished = true;
                    }
                }
                JournalRecord::RoundLeaseChanged { job_id, residue_owners } => {
                    if let Some(j) = meta.jobs.get_mut(&job_id) {
                        // Same-length invariant: the lease table always has
                        // one entry per residue class. A malformed record
                        // (partial write never survives the CRC framing;
                        // this is belt) is ignored rather than corrupting
                        // the table shape.
                        if residue_owners.len() == j.worker_order.len() {
                            j.residue_owners = residue_owners;
                        }
                    }
                }
                JournalRecord::ConsumerSetChanged { job_id, epoch, barrier_round, num_consumers } => {
                    if let Some(j) = meta.jobs.get_mut(&job_id) {
                        // Monotone append: a duplicate or stale record
                        // (possible across a crash between append and
                        // publish) replays as a no-op.
                        if j.width_epochs.last().map(|e| epoch > e.epoch).unwrap_or(true) {
                            j.width_epochs.push(WidthEpoch { epoch, barrier_round, num_consumers });
                            j.num_consumers = num_consumers;
                        }
                    }
                }
                JournalRecord::SnapshotCommitted { fingerprint, epoch, manifest } => {
                    // Epoch-monotone last-writer-wins per fingerprint: a
                    // duplicate (crash between append and publish) or a
                    // stale record replays as a no-op.
                    let newer = meta
                        .snapshots
                        .get(&fingerprint)
                        .map(|m| epoch >= m.epoch)
                        .unwrap_or(true);
                    if newer {
                        if let Some(j) = meta.jobs.get_mut(&manifest.job_id) {
                            j.snapshot_committed = true;
                        }
                        meta.snapshots.insert(fingerprint, manifest);
                    }
                }
                JournalRecord::WorkerDrainChanged { worker_id, draining } => {
                    // Last-writer-wins per worker. In-flight handoff and
                    // revocation queues are soft state: the first
                    // post-restart tick re-plans them from this flag and
                    // the replayed lease table.
                    if let Some(w) = meta.workers.get_mut(&worker_id) {
                        w.draining = draining;
                        if !draining {
                            w.drain_ready = false;
                        }
                    }
                }
                JournalRecord::SpillSnapshotGced { job_id } => {
                    // No meta change: the superseding SnapshotCommitted
                    // that preceded this record already replaced the
                    // fingerprint's manifest. The caller re-issues the
                    // (idempotent) store deletes.
                    gced.push(job_id);
                }
            }
        }
        gced
    }

    /// Serialize the full replayable dispatcher state into one
    /// [`DispatcherSnapshot`] — what a complete journal replay up to this
    /// instant would rebuild. Also the compaction cut
    /// ([`Dispatcher::compact_now`] / the `tick()` threshold).
    pub fn snapshot_state(&self) -> DispatcherSnapshot {
        snapshot_from_meta(&self.state.meta.lock().unwrap())
    }

    /// Cut a checkpoint *now*: snapshot the current meta and install it
    /// via [`Journal::install_snapshot`] (temp-file + atomic rename +
    /// fresh suffix + retention). Holds the meta lock across cut and
    /// install: every journaled record is applied to meta before the
    /// cut (all append sites hold this lock), and none lands between
    /// the cut and the suffix swap — the write-ahead ordering is exact.
    /// Returns the new snapshot sequence, or `None` without a journal
    /// (or on a write failure, which leaves the old suffix growing —
    /// durability is unaffected, only boundedness, and the next trigger
    /// retries).
    pub fn compact_now(&self) -> Option<u64> {
        let meta = self.state.meta.lock().unwrap();
        self.compact_locked(&meta)
    }

    fn compact_locked(&self, meta: &Meta) -> Option<u64> {
        let journal = self.state.journal.as_ref()?;
        let snap = snapshot_from_meta(meta);
        match journal.install_snapshot(&snap) {
            Ok(seq) => {
                self.state.metrics.counter("dispatcher/snapshots_written").inc();
                self.state.metrics.counter("dispatcher/journal_compactions").inc();
                Some(seq)
            }
            Err(_) => {
                self.state.metrics.counter("dispatcher/snapshot_write_failures").inc();
                None
            }
        }
    }

    pub fn addr(&self) -> String {
        self.server.local_addr().to_string()
    }

    pub fn metrics(&self) -> &Registry {
        &self.state.metrics
    }

    /// Declare workers dead whose heartbeat is older than the timeout;
    /// their in-flight dynamic splits are recorded as lost and their
    /// coordinated **round leases are reassigned** to surviving owners
    /// (§3.6 fault tolerance: a lease is renewed by heartbeating, so a
    /// silent worker forfeits its round residues instead of stalling
    /// every consumer at its next round forever). Residues adopted by
    /// survivors are **re-balanced back** to a revived home owner once it
    /// has stayed alive past `revival_hysteresis`. Every lease-table
    /// change is journaled (`RoundLeaseChanged`), so the table survives a
    /// dispatcher restart. Returns the failed worker ids. Called by the
    /// orchestrator's control loop.
    pub fn tick(&self) -> Vec<u64> {
        let mut meta = self.state.meta.lock().unwrap();
        let timeout = self.state.cfg.worker_timeout;
        let now = Instant::now();
        let dead: Vec<u64> = meta
            .workers
            .iter()
            .filter(|(_, w)| w.alive && now.duration_since(w.last_heartbeat) > timeout)
            .map(|(&id, _)| id)
            .collect();
        for id in &dead {
            if let Some(w) = meta.workers.get_mut(id) {
                w.alive = false;
                w.assigned.clear();
                w.pending_tasks.clear();
                w.pending_attach.clear();
                w.pending_detach.clear();
                w.pending_rounds.clear();
                w.pending_widths.clear();
                w.pending_revocations.clear();
                w.drain_ready = false;
            }
            for job in meta.jobs.values() {
                if let Some(t) = &job.tracker {
                    t.worker_failed(*id);
                }
            }
            self.state.metrics.counter("dispatcher/workers_failed").inc();
        }
        // Consumer progress reports are leases too: an entry silent past
        // the worker timeout belongs to a crashed consumer — drop it so
        // it cannot pin the job floor forever (the all-slots gate in
        // `JobState::floor` keeps the floor conservative until the
        // replacement re-reports). Stall reports age out the same way.
        for job in meta.jobs.values_mut() {
            job.client_rounds.retain(|_, &mut (_, at)| now.duration_since(at) <= timeout);
            job.client_stalls.retain(|_, &mut (_, at)| now.duration_since(at) <= timeout);
        }
        // Cancel two-phase handoffs whose loser died mid-handshake —
        // before failure reassignment, so the residue (still leased to
        // the now-dead loser) is immediately re-homed by the ordinary
        // dead-owner path. A dead loser cannot co-hold, so the direct
        // flip is safe there.
        {
            let workers = &meta.workers;
            let alive = |w: u64| workers.get(&w).map(|wi| wi.alive).unwrap_or(false);
            for job in meta.jobs.values_mut() {
                job.pending_handoffs.retain(|h| alive(h.loser));
            }
        }
        let mut lease_changed = Vec::new();
        // Failure reassignment runs every tick, not just on a death
        // *transition* (it is idempotent and returns nothing when no
        // owner is dead): a residue can point at a dead worker without a
        // fresh transition — e.g. every owner died with no survivor to
        // lease to, and a later revival brought capacity back — and must
        // be re-homed as soon as any live owner exists again.
        lease_changed.extend(reassign_round_leases(&mut meta, &self.state.metrics));
        // The live-to-live movers (revival re-balance, graceful drain)
        // only *plan* two-phase handoffs here: the lease table is not
        // touched until the loser's revoke ack arrives on a heartbeat.
        // The exception is a *dead* holder blocking a revived home owner
        // (nothing can co-hold with a corpse): that flips directly and
        // is journaled below like any dead-owner move.
        lease_changed.extend(plan_revival_handoffs(
            &mut meta,
            self.state.cfg.revival_hysteresis,
            &self.state.metrics,
        ));
        plan_drain_lease_handoffs(&mut meta, &self.state.metrics);
        lease_changed.sort_unstable();
        lease_changed.dedup();
        // Journal the new lease layout. Crash before the append just
        // restores the previous table on replay: the dead owners are
        // still dead, so the next tick redoes the (idempotent) move.
        for job_id in lease_changed {
            if let Some(j) = meta.jobs.get(&job_id) {
                let _ = journal_append(
                    &self.state,
                    &JournalRecord::RoundLeaseChanged {
                        job_id,
                        residue_owners: j.residue_owners.clone(),
                    },
                );
            }
        }
        // Automatic compaction, off the RPC hot path: when the live
        // suffix outgrew the byte threshold, cut a checkpoint while
        // `meta` is still held — every append site holds this lock, so
        // the journal's contents and the applied meta agree exactly at
        // the cut, and no record can land between cut and suffix swap.
        if self.state.cfg.journal_compact_bytes > 0 {
            if let Some(j) = &self.state.journal {
                if j.suffix_bytes() >= self.state.cfg.journal_compact_bytes {
                    self.compact_locked(&meta);
                }
            }
        }
        dead
    }

    // ---- local (non-RPC) accessors used by tests, benches, examples ----

    pub fn num_live_workers(&self) -> usize {
        self.state.meta.lock().unwrap().workers.values().filter(|w| w.alive).count()
    }

    pub fn job_clients(&self, job_id: u64) -> usize {
        self.state.meta.lock().unwrap().jobs.get(&job_id).map(|j| j.clients.len()).unwrap_or(0)
    }

    pub fn job_split_stats(&self, job_id: u64) -> Option<(usize, usize, usize)> {
        let meta = self.state.meta.lock().unwrap();
        let t = meta.jobs.get(&job_id)?.tracker.as_ref()?;
        Some((t.remaining(), t.completed().len(), t.lost().len()))
    }

    /// Change a coordinated job's consumer width mid-job (elastic
    /// membership; also served over RPC as
    /// [`dispatcher_methods::SET_JOB_CONSUMERS`]). Returns the
    /// `(epoch, barrier_round)` at which the new width takes effect.
    pub fn set_job_consumers(&self, job_id: u64, num_consumers: u32) -> ServiceResult<(u32, u64)> {
        let resp = set_job_consumers(&self.state, SetJobConsumersReq { job_id, num_consumers })?;
        Ok((resp.epoch, resp.barrier_round))
    }

    // ---- graceful drain (two-phase scale-down) ----

    /// Enter the `Draining` state: journal the transition, stop routing
    /// new consumers to the worker, and let the next `tick()` plan
    /// revoke-ack-grant handoffs for every residue it owns. Returns
    /// `false` when the worker was already draining (idempotent).
    pub fn begin_worker_drain(&self, worker_id: u64) -> ServiceResult<bool> {
        {
            let mut meta = self.state.meta.lock().unwrap();
            match meta.workers.get(&worker_id) {
                None => return Err(ServiceError::UnknownWorker(worker_id)),
                Some(w) if w.draining => return Ok(false),
                Some(_) => {}
            }
            // Journaled before applied, under one continuous `meta`
            // section (see `journal_append`'s invariant): a restart
            // mid-drain resumes the drain (re-plans handoffs from the
            // flag + replayed lease table) instead of silently
            // re-admitting a half-drained worker.
            journal_append(
                &self.state,
                &JournalRecord::WorkerDrainChanged { worker_id, draining: true },
            )?;
            if let Some(w) = meta.workers.get_mut(&worker_id) {
                w.draining = true;
                w.drain_ready = false;
            }
        }
        self.state.metrics.counter("dispatcher/worker_drains_started").inc();
        Ok(true)
    }

    /// True when nothing on `worker_id` remains to hand off: the worker
    /// is gone (unknown or declared dead — there is nothing left to wait
    /// for), or it reported drain-ready, every revocation was acked, and
    /// it holds no residue (and no pending handoff) in any live
    /// coordinated job. The orchestrator polls this before removing a
    /// draining worker.
    pub fn drain_complete(&self, worker_id: u64) -> bool {
        let meta = self.state.meta.lock().unwrap();
        let Some(w) = meta.workers.get(&worker_id) else { return true };
        if !w.alive {
            return true;
        }
        if !w.draining || !w.drain_ready || !w.pending_revocations.is_empty() {
            return false;
        }
        !meta.jobs.values().any(|j| {
            !j.finished
                && j.mode == ProcessingMode::Coordinated
                && (j.residue_owners.contains(&worker_id)
                    || j.pending_handoffs.iter().any(|h| h.loser == worker_id))
        })
    }

    /// Record a completed drain: journal the exit from `Draining`, count
    /// `dispatcher/workers_drained`, and retire the entry (dead, queues
    /// cleared) so clients stop resolving it immediately instead of
    /// after `worker_timeout`. Called by the orchestrator right after it
    /// removes the (now state-free) worker.
    pub fn finish_worker_drain(&self, worker_id: u64) -> ServiceResult<()> {
        let was_draining = {
            let mut meta = self.state.meta.lock().unwrap();
            // Write-ahead under the same `meta` section (see
            // `journal_append`'s invariant): the drain-exit record must
            // be durable before the retirement it describes is applied,
            // or a snapshot cut between apply and append would disagree
            // with the journal.
            if matches!(meta.workers.get(&worker_id), Some(w) if w.draining) {
                journal_append(
                    &self.state,
                    &JournalRecord::WorkerDrainChanged { worker_id, draining: false },
                )?;
            }
            let retired = match meta.workers.get_mut(&worker_id) {
                Some(w) if w.draining => {
                    w.draining = false;
                    w.drain_ready = false;
                    w.alive = false;
                    w.confirmed = false;
                    w.assigned.clear();
                    w.pending_tasks.clear();
                    w.pending_attach.clear();
                    w.pending_detach.clear();
                    w.pending_rounds.clear();
                    w.pending_widths.clear();
                    w.pending_revocations.clear();
                    true
                }
                _ => false,
            };
            if retired {
                for job in meta.jobs.values() {
                    if let Some(t) = &job.tracker {
                        t.worker_failed(worker_id);
                    }
                }
            }
            retired
        };
        if was_draining {
            self.state.metrics.counter("dispatcher/workers_drained").inc();
        }
        Ok(())
    }

    /// Whether `worker_id` is currently held in the `Draining` state.
    pub fn worker_draining(&self, worker_id: u64) -> bool {
        self.state
            .meta
            .lock()
            .unwrap()
            .workers
            .get(&worker_id)
            .map(|w| w.draining)
            .unwrap_or(false)
    }

    /// Scale-down victim pick: the alive, non-draining worker among
    /// `candidates` with the lowest heartbeat-reported CPU (ties broken
    /// by id for determinism).
    pub fn least_loaded_worker(&self, candidates: &[u64]) -> Option<u64> {
        let meta = self.state.meta.lock().unwrap();
        candidates
            .iter()
            .copied()
            .filter_map(|id| meta.workers.get(&id).map(|w| (id, w)))
            .filter(|(_, w)| w.alive && !w.draining)
            .min_by_key(|&(id, w)| (w.last_cpu_milli, id))
            .map(|(id, _)| id)
    }

    /// Aggregate the closed-loop autoscaling inputs: per-worker CPU from
    /// worker heartbeats and per-client stall fractions from client
    /// heartbeats, reduced to one controller evaluation's worth of
    /// signals. Draining workers are excluded from capacity (they are
    /// already on their way out) and from the utilization mean.
    pub fn scaling_snapshot(&self) -> ScalingSnapshot {
        let meta = self.state.meta.lock().unwrap();
        let mut live = 0usize;
        let mut draining = 0usize;
        let mut util_sum = 0u64;
        for w in meta.workers.values() {
            if !w.alive {
                continue;
            }
            if w.draining {
                draining += 1;
            } else {
                live += 1;
                util_sum += w.last_cpu_milli as u64;
            }
        }
        let mut stall_sum = 0u64;
        let mut stall_n = 0usize;
        let mut active_jobs = 0usize;
        for j in meta.jobs.values() {
            if j.finished {
                continue;
            }
            active_jobs += 1;
            for &(milli, _) in j.client_stalls.values() {
                stall_sum += milli as u64;
                stall_n += 1;
            }
        }
        ScalingSnapshot {
            live_workers: live,
            draining_workers: draining,
            mean_worker_util: if live > 0 {
                (util_sum as f64 / live as f64 / 1000.0).min(1.0)
            } else {
                0.0
            },
            client_starvation: if stall_n > 0 {
                (stall_sum as f64 / stall_n as f64 / 1000.0).min(1.0)
            } else {
                0.0
            },
            active_jobs,
        }
    }
}

/// One controller evaluation's worth of aggregated autoscaling inputs
/// (see [`Dispatcher::scaling_snapshot`]).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ScalingSnapshot {
    /// Alive, non-draining workers — the capacity the controller sizes.
    pub live_workers: usize,
    /// Workers currently in the `Draining` state (scale-down in flight).
    pub draining_workers: usize,
    /// Mean heartbeat-reported CPU utilization over live workers, [0, 1].
    pub mean_worker_util: f64,
    /// Mean client-reported input-stall fraction over fresh reports,
    /// [0, 1]; 0 when no client has reported.
    pub client_starvation: f64,
    /// Unfinished jobs currently tracked.
    pub active_jobs: usize,
}

/// Pure lease-table transition behind failure reassignment: move every
/// residue held by a non-alive owner to a surviving lease holder (stable
/// round-robin over the sorted survivor set, so concurrent dispatchers
/// replaying the same inputs converge). Returns the gaining worker ids
/// (deduped); an empty result means nothing moved (no dead owner, or no
/// survivor to lease to). Exposed so the property tests drive the exact
/// policy the dispatcher ships.
pub fn reassign_dead_residues(owners: &mut [u64], alive: &dyn Fn(u64) -> bool) -> Vec<u64> {
    let mut survivors: Vec<u64> = owners.iter().copied().filter(|&w| alive(w)).collect();
    survivors.sort_unstable();
    survivors.dedup();
    if survivors.is_empty() {
        return Vec::new(); // nobody to lease to; clients stall until workers return
    }
    let mut next = 0usize;
    let mut gained = Vec::new();
    for owner in owners.iter_mut() {
        if !alive(*owner) {
            *owner = survivors[next % survivors.len()];
            next += 1;
            gained.push(*owner);
        }
    }
    gained.sort_unstable();
    gained.dedup();
    gained
}

/// Pure planning step behind revival re-balance: residue `i` should move
/// back to its home owner `worker_order[i]` when the home owner is
/// `eligible` (alive, confirmed, past the hysteresis window — judged by
/// the caller), someone else currently holds it, and no handoff is
/// already `pending` for it. Unlike the pre-drain implementation this
/// does NOT mutate the lease table: it returns `(residue, loser, gainer)`
/// plans whose flips activate only once the loser acks revocation, so a
/// residue is never co-held by two live owners. Exposed for the property
/// tests, like [`reassign_dead_residues`].
pub fn plan_home_handoffs(
    owners: &[u64],
    worker_order: &[u64],
    eligible: &dyn Fn(u64) -> bool,
    pending: &dyn Fn(usize) -> bool,
) -> Vec<(usize, u64, u64)> {
    let mut plans = Vec::new();
    for (i, &owner) in owners.iter().enumerate() {
        let Some(&home) = worker_order.get(i) else { continue };
        if owner != home && eligible(home) && !pending(i) {
            plans.push((i, owner, home));
        }
    }
    plans
}

/// Pure planning step behind graceful drain: every residue whose owner
/// is `draining` moves to a non-draining gainer — the residue's home
/// owner `worker_order[i]` when it is among `candidates` (alive,
/// confirmed, non-draining — judged by the caller), else round-robin
/// over the sorted candidate set. Residues with a handoff already
/// `pending` are skipped. Like [`plan_home_handoffs`] this only plans:
/// the lease table is untouched until the draining loser acks
/// revocation. Returns `(residue, loser, gainer)` plans.
pub fn plan_drain_handoffs(
    owners: &[u64],
    worker_order: &[u64],
    draining: &dyn Fn(u64) -> bool,
    candidates: &[u64],
    pending: &dyn Fn(usize) -> bool,
) -> Vec<(usize, u64, u64)> {
    if candidates.is_empty() {
        return Vec::new(); // nowhere to drain to; residues stay put
    }
    let mut next = 0usize;
    let mut plans = Vec::new();
    for (i, &owner) in owners.iter().enumerate() {
        if !draining(owner) || pending(i) {
            continue;
        }
        let home = worker_order.get(i).copied();
        let gainer = match home.filter(|h| candidates.contains(h)) {
            Some(h) => h,
            None => {
                let g = candidates[next % candidates.len()];
                next += 1;
                g
            }
        };
        if gainer != owner {
            plans.push((i, owner, gainer));
        }
    }
    plans
}

/// Shared grant-building step of the lease-move paths
/// ([`reassign_round_leases`] and [`Dispatcher`]'s handoff completion):
/// for each affected worker, its *full* updated owned-residue set from
/// the job's lease table, floored at the minimum round any consumer
/// still needs. One code path builds every lease-view grant, so the
/// movers cannot diverge on what a worker is told it owns.
fn collect_lease_grants(job_id: u64, job: &JobState, affected: &[u64]) -> Vec<(u64, RoundAssignment)> {
    let floor = job.floor();
    affected
        .iter()
        .map(|&w| {
            let owned_residues: Vec<u32> = job
                .residue_owners
                .iter()
                .enumerate()
                .filter(|(_, &o)| o == w)
                .map(|(i, _)| i as u32)
                .collect();
            (w, RoundAssignment { job_id, owned_residues, start_round: floor })
        })
        .collect()
}

/// Queue collected grants for delivery on live workers' next heartbeats
/// (the other half of the shared grant-queueing path). Dead workers are
/// skipped: their queues were cleared at death, and their authoritative
/// view is re-pushed on their first heartbeat back anyway.
fn queue_lease_grants(meta: &mut Meta, grants: Vec<(u64, RoundAssignment)>) {
    for (worker_id, grant) in grants {
        if let Some(w) = meta.workers.get_mut(&worker_id) {
            if w.alive {
                w.pending_rounds.push(grant);
            }
        }
    }
}

/// Move every dead owner's round residues to surviving lease holders and
/// queue the updated assignments for delivery on the gaining workers'
/// next heartbeats. The materialization floor handed to a new owner is
/// the minimum `next_round` any consumer reported — rounds every
/// consumer already consumed are never re-labeled, and rounds a slower
/// consumer still needs get re-materialized from the new owner's own
/// pipeline (relaxed visitation under failure). Returns the jobs whose
/// lease table changed (for journaling).
fn reassign_round_leases(meta: &mut Meta, metrics: &Registry) -> Vec<u64> {
    // Collect per-job reassignments first (cannot mutate workers while
    // iterating jobs).
    let mut grants: Vec<(u64, RoundAssignment)> = Vec::new();
    let mut changed_jobs = Vec::new();
    for (&job_id, job) in meta.jobs.iter_mut() {
        if job.finished || job.mode != ProcessingMode::Coordinated || job.residue_owners.is_empty()
        {
            continue;
        }
        let workers = &meta.workers;
        let alive = |w: u64| workers.get(&w).map(|wi| wi.alive).unwrap_or(false);
        let gained = reassign_dead_residues(&mut job.residue_owners, &alive);
        if gained.is_empty() {
            continue;
        }
        changed_jobs.push(job_id);
        for _ in &gained {
            metrics.counter("dispatcher/round_leases_reassigned").inc();
        }
        grants.extend(collect_lease_grants(job_id, job, &gained));
    }
    queue_lease_grants(meta, grants);
    changed_jobs
}

/// Merge one residue into the loser's pending revocation queue for
/// `job_id` (create the entry if absent, skip duplicates). Entries are
/// re-delivered on every heartbeat until acked, so queueing is
/// idempotent by construction.
fn queue_revocation(meta: &mut Meta, loser: u64, job_id: u64, residue: u32) {
    let Some(w) = meta.workers.get_mut(&loser) else { return };
    match w.pending_revocations.iter_mut().find(|r| r.job_id == job_id) {
        Some(r) => {
            if !r.residues.contains(&residue) {
                r.residues.push(residue);
            }
        }
        None => {
            w.pending_revocations.push(LeaseRevoke { job_id, residues: vec![residue] });
        }
    }
}

/// Revival re-balance (§3.6, ROADMAP PR 4 follow-up), two-phase edition:
/// *plan* handing residues back to a home owner that has been alive past
/// the hysteresis window, so a recovered worker resumes serving its
/// share instead of staying leaseless until another failure. Phase 1
/// queues a revocation on the current (live) holder; the lease table and
/// the gainer's grant do not move until the holder acks on a heartbeat
/// ([`complete_lease_handoffs`]), closing the PR 5 relaxation where
/// loser and gainer briefly co-held a residue. A *dead* holder cannot
/// ack (and cannot co-hold), so its residues flip directly — covering
/// the every-owner-died-then-home-revived corner, where failure
/// reassignment has no surviving holder to lease to. Returns the jobs
/// whose lease table changed by such direct flips (for journaling).
fn plan_revival_handoffs(meta: &mut Meta, hysteresis: Duration, metrics: &Registry) -> Vec<u64> {
    let now = Instant::now();
    let mut revocations: Vec<(u64, u64, u32)> = Vec::new(); // (loser, job, residue)
    let mut grants: Vec<(u64, RoundAssignment)> = Vec::new();
    let mut changed_jobs = Vec::new();
    for (&job_id, job) in meta.jobs.iter_mut() {
        if job.finished
            || job.mode != ProcessingMode::Coordinated
            || job.residue_owners.is_empty()
            || job.worker_order.is_empty()
        {
            continue;
        }
        let workers = &meta.workers;
        let alive = |w: u64| workers.get(&w).map(|wi| wi.alive).unwrap_or(false);
        // Eligible = alive, *confirmed by a heartbeat of its current
        // incarnation* (a journal-restored worker may be a corpse under
        // failure-detection grace), not draining (a worker on its way
        // out must not gain leases), and past the hysteresis window.
        let eligible = |w: u64| {
            workers
                .get(&w)
                .map(|wi| {
                    wi.alive
                        && wi.confirmed
                        && !wi.draining
                        && now.duration_since(wi.alive_since) >= hysteresis
                })
                .unwrap_or(false)
        };
        let handoffs = &job.pending_handoffs;
        let pending = |i: usize| handoffs.iter().any(|h| h.residue == i as u32);
        let plans = plan_home_handoffs(&job.residue_owners, &job.worker_order, &eligible, &pending);
        let mut direct_gainers: Vec<u64> = Vec::new();
        for (residue, loser, gainer) in plans {
            if !alive(loser) {
                job.residue_owners[residue] = gainer;
                direct_gainers.push(gainer);
                metrics.counter("dispatcher/round_leases_rebalanced").inc();
                continue;
            }
            job.pending_handoffs.push(PendingHandoff {
                residue: residue as u32,
                loser,
                gainer,
                home: true,
            });
            revocations.push((loser, job_id, residue as u32));
            metrics.counter("dispatcher/lease_handoffs_planned").inc();
        }
        if !direct_gainers.is_empty() {
            direct_gainers.sort_unstable();
            direct_gainers.dedup();
            changed_jobs.push(job_id);
            grants.extend(collect_lease_grants(job_id, job, &direct_gainers));
        }
    }
    for (loser, job_id, residue) in revocations {
        queue_revocation(meta, loser, job_id, residue);
    }
    queue_lease_grants(meta, grants);
    changed_jobs
}

/// Graceful-drain lease planning: for every draining worker, plan moving
/// each residue it owns to a fit (alive, confirmed, non-draining) gainer
/// via the same two-phase revoke-ack-grant path as revival re-balance.
/// The draining worker keeps serving its residues until it acks — new
/// round data just stops being routed its way — so clients never observe
/// an ownerless residue during scale-down.
fn plan_drain_lease_handoffs(meta: &mut Meta, metrics: &Registry) {
    let any_draining = meta.workers.values().any(|w| w.alive && w.draining);
    if !any_draining {
        return;
    }
    let mut candidates: Vec<u64> = meta
        .workers
        .iter()
        .filter(|(_, w)| w.alive && w.confirmed && !w.draining)
        .map(|(&id, _)| id)
        .collect();
    candidates.sort_unstable();
    let mut revocations: Vec<(u64, u64, u32)> = Vec::new();
    for (&job_id, job) in meta.jobs.iter_mut() {
        if job.finished || job.mode != ProcessingMode::Coordinated || job.residue_owners.is_empty()
        {
            continue;
        }
        let workers = &meta.workers;
        let draining = |w: u64| {
            workers.get(&w).map(|wi| wi.alive && wi.draining).unwrap_or(false)
        };
        let handoffs = &job.pending_handoffs;
        let pending = |i: usize| handoffs.iter().any(|h| h.residue == i as u32);
        let plans = plan_drain_handoffs(
            &job.residue_owners,
            &job.worker_order,
            &draining,
            &candidates,
            &pending,
        );
        for (residue, loser, gainer) in plans {
            job.pending_handoffs.push(PendingHandoff {
                residue: residue as u32,
                loser,
                gainer,
                home: false,
            });
            revocations.push((loser, job_id, residue as u32));
            metrics.counter("dispatcher/lease_handoffs_planned").inc();
        }
    }
    for (loser, job_id, residue) in revocations {
        queue_revocation(meta, loser, job_id, residue);
    }
}

/// Phase 2 of the revoke-ack-grant handoff, driven by the loser's
/// heartbeat acks: clear acked residues from the loser's revocation
/// queue, flip the lease table to the planned gainer (re-picked if the
/// planned one died or started draining since), journal the change, and
/// queue full lease-view grants for the gainers. Because the flip
/// happens strictly after the loser stopped serving (it acks only after
/// applying the revocation and flushing spill), no residue is ever
/// co-held by two live owners.
fn complete_lease_handoffs(
    state: &State,
    meta: &mut Meta,
    worker_id: u64,
    acks: &[LeaseRevoke],
) -> ServiceResult<()> {
    if acks.is_empty() {
        return Ok(());
    }
    if let Some(w) = meta.workers.get_mut(&worker_id) {
        for ack in acks {
            if let Some(pending) =
                w.pending_revocations.iter_mut().find(|r| r.job_id == ack.job_id)
            {
                pending.residues.retain(|r| !ack.residues.contains(r));
            }
        }
        w.pending_revocations.retain(|r| !r.residues.is_empty());
    }
    let mut changed_jobs: Vec<u64> = Vec::new();
    let mut affected: Vec<(u64, u64)> = Vec::new(); // (job, gainer)
    for ack in acks {
        let Some(job) = meta.jobs.get(&ack.job_id) else { continue };
        if job.finished {
            continue;
        }
        for &residue in &ack.residues {
            // Re-borrow per residue: the fitness check needs `meta.workers`
            // while the flip needs `meta.jobs` mutably.
            let Some(job) = meta.jobs.get_mut(&ack.job_id) else { break };
            let Some(pos) = job
                .pending_handoffs
                .iter()
                .position(|h| h.residue == residue && h.loser == worker_id)
            else {
                // No matching plan: the handoff was canceled (loser died
                // and failure reassignment already re-homed the residue)
                // — the ack only needed to clear the revocation above.
                continue;
            };
            let h = job.pending_handoffs.remove(pos);
            let workers = &meta.workers;
            let fit = |w: u64| {
                workers
                    .get(&w)
                    .map(|wi| wi.alive && wi.confirmed && !wi.draining)
                    .unwrap_or(false)
            };
            let gainer = if fit(h.gainer) {
                h.gainer
            } else {
                // Planned gainer became unfit while the revocation was in
                // flight: fall back to the first fit worker (sorted, for
                // determinism), else back to the loser itself — the next
                // tick() will re-plan the move.
                let mut ids: Vec<u64> = workers
                    .iter()
                    .filter(|(_, wi)| wi.alive && wi.confirmed && !wi.draining)
                    .map(|(&id, _)| id)
                    .collect();
                ids.sort_unstable();
                ids.first().copied().unwrap_or(h.loser)
            };
            if let Some(slot) = job.residue_owners.get_mut(residue as usize) {
                *slot = gainer;
            }
            changed_jobs.push(ack.job_id);
            affected.push((ack.job_id, gainer));
            state.metrics.counter("dispatcher/lease_handoffs_completed").inc();
            if h.home {
                state.metrics.counter("dispatcher/round_leases_rebalanced").inc();
            }
        }
    }
    changed_jobs.sort_unstable();
    changed_jobs.dedup();
    affected.sort_unstable();
    affected.dedup();
    let mut grants: Vec<(u64, RoundAssignment)> = Vec::new();
    for &job_id in &changed_jobs {
        if let Some(job) = meta.jobs.get(&job_id) {
            let gainers: Vec<u64> = affected
                .iter()
                .filter(|(j, _)| *j == job_id)
                .map(|&(_, g)| g)
                .collect();
            grants.extend(collect_lease_grants(job_id, job, &gainers));
        }
    }
    queue_lease_grants(meta, grants);
    for job_id in changed_jobs {
        if let Some(job) = meta.jobs.get(&job_id) {
            journal_append(
                state,
                &JournalRecord::RoundLeaseChanged {
                    job_id,
                    residue_owners: job.residue_owners.clone(),
                },
            )?;
        }
    }
    Ok(())
}

/// Append one record under write-ahead semantics. **Invariant: every
/// caller holds the `meta` lock across the append *and* the matching
/// meta mutation.** Compaction (which also holds `meta`) therefore
/// always cuts a snapshot that agrees byte-for-byte with the journal's
/// applied contents — a record can never be durable-but-unapplied (it
/// would be deleted with the retiring suffix yet absent from the
/// snapshot) or applied-but-undurable (it would be captured by the
/// snapshot, which is fine, or lost with a crash like any un-acked
/// write-ahead record). The journal has its own lock and never takes
/// `meta`, so appending under `meta` cannot deadlock.
fn journal_append(state: &State, rec: &JournalRecord) -> ServiceResult<()> {
    if let Some(j) = &state.journal {
        j.append(rec).map_err(|e| ServiceError::Journal(e.to_string()))?;
    }
    Ok(())
}

/// Canonical-order serialization of the journal-derivable meta fields
/// (the compaction cut and the restore-equivalence test's comparison
/// key). Maps become key-sorted vectors; soft state is excluded.
fn snapshot_from_meta(meta: &Meta) -> DispatcherSnapshot {
    let mut datasets: Vec<(u64, GraphDef)> =
        meta.datasets.iter().map(|(&id, g)| (id, g.clone())).collect();
    datasets.sort_by_key(|&(id, _)| id);
    let mut jobs: Vec<SnapshotJob> = meta
        .jobs
        .iter()
        .map(|(&job_id, j)| {
            let mut clients: Vec<u64> = j.clients.iter().copied().collect();
            clients.sort_unstable();
            SnapshotJob {
                job_id,
                dataset_id: j.dataset_id,
                job_name: j.job_name.clone(),
                sharding: j.sharding,
                mode: j.mode,
                num_consumers: j.num_consumers,
                sharing: j.sharing,
                worker_order: j.worker_order.clone(),
                residue_owners: j.residue_owners.clone(),
                clients,
                finished: j.finished,
                width_epochs: j.width_epochs.clone(),
                snapshot_serve: j.snapshot_serve,
                snapshot_committed: j.snapshot_committed,
            }
        })
        .collect();
    jobs.sort_by_key(|j| j.job_id);
    let mut named_jobs: Vec<SnapshotNamedJob> = meta
        .named_jobs
        .iter()
        .map(|((dataset_id, job_name), &job_id)| SnapshotNamedJob {
            dataset_id: *dataset_id,
            job_name: job_name.clone(),
            job_id,
        })
        .collect();
    named_jobs.sort_by(|a, b| (a.dataset_id, &a.job_name).cmp(&(b.dataset_id, &b.job_name)));
    let mut workers: Vec<SnapshotWorker> = meta
        .workers
        .iter()
        .map(|(&worker_id, w)| SnapshotWorker {
            worker_id,
            addr: w.addr.clone(),
            draining: w.draining,
        })
        .collect();
    workers.sort_by_key(|w| w.worker_id);
    let mut spill_snapshots: Vec<(u64, SpillManifest)> =
        meta.snapshots.iter().map(|(&fp, m)| (fp, m.clone())).collect();
    spill_snapshots.sort_by_key(|&(fp, _)| fp);
    DispatcherSnapshot {
        datasets,
        jobs,
        named_jobs,
        workers,
        spill_snapshots,
        next_worker_id: meta.next_worker_id,
        next_job_id: meta.next_job_id,
        next_client_id: meta.next_client_id,
    }
}

/// RPC demux.
fn handle(state: &Arc<State>, method: u16, payload: &[u8]) -> ServiceResult<Vec<u8>> {
    use dispatcher_methods as m;
    match method {
        m::REGISTER_DATASET => {
            let req = RegisterDatasetReq::from_bytes(payload)?;
            Ok(register_dataset(state, req)?.to_bytes())
        }
        m::GET_OR_CREATE_JOB => {
            let req = GetOrCreateJobReq::from_bytes(payload)?;
            Ok(get_or_create_job(state, req)?.to_bytes())
        }
        m::CLIENT_HEARTBEAT => {
            let req = ClientHeartbeatReq::from_bytes(payload)?;
            Ok(client_heartbeat(state, req)?.to_bytes())
        }
        m::REGISTER_WORKER => {
            let req = RegisterWorkerReq::from_bytes(payload)?;
            Ok(register_worker(state, req)?.to_bytes())
        }
        m::WORKER_HEARTBEAT => {
            let req = WorkerHeartbeatReq::from_bytes(payload)?;
            Ok(worker_heartbeat(state, req)?.to_bytes())
        }
        m::GET_SPLIT => {
            let req = GetSplitReq::from_bytes(payload)?;
            Ok(get_split(state, req)?.to_bytes())
        }
        m::RELEASE_JOB => {
            let req = ReleaseJobReq::from_bytes(payload)?;
            Ok(release_job(state, req)?.to_bytes())
        }
        m::SET_JOB_CONSUMERS => {
            let req = SetJobConsumersReq::from_bytes(payload)?;
            Ok(set_job_consumers(state, req)?.to_bytes())
        }
        other => Err(ServiceError::Other(format!("dispatcher: unknown method {other}"))),
    }
}

fn register_dataset(state: &Arc<State>, req: RegisterDatasetReq) -> ServiceResult<RegisterDatasetResp> {
    req.graph.validate().map_err(|e| ServiceError::Other(format!("invalid graph: {e}")))?;
    // Canonical structural fingerprint, with client-supplied UDF body
    // digests mixed in: this IS the dataset id, so identical pipelines
    // collide regardless of who registers them, in what order, or with
    // what performance tuning — the discovery mechanism behind §3.5.
    let digest_of = |name: &str| {
        req.udf_digests.iter().find(|d| d.name == name).map(|d| d.digest)
    };
    let full = req.graph.fingerprint_full(&digest_of);
    let dataset_id = u64::from_le_bytes(full[..8].try_into().unwrap());
    {
        // Check + journal + apply under one continuous `meta` section
        // (see `journal_append`'s invariant).
        let mut meta = state.meta.lock().unwrap();
        if meta.datasets.contains_key(&dataset_id) {
            // Identical pipeline already registered (fingerprint match).
            return Ok(RegisterDatasetResp { dataset_id, fingerprint: full.to_vec() });
        }
        journal_append(
            state,
            &JournalRecord::RegisterDataset { dataset_id, graph: req.graph.clone() },
        )?;
        meta.datasets.insert(dataset_id, req.graph);
    }
    state.metrics.counter("dispatcher/datasets_registered").inc();
    Ok(RegisterDatasetResp { dataset_id, fingerprint: full.to_vec() })
}

fn make_task(
    meta: &Meta,
    job_id: u64,
    job: &JobState,
    graph: &GraphDef,
    worker_id: u64,
    static_shards: Vec<u64>,
) -> TaskDef {
    let worker_index = job.worker_order.iter().position(|&w| w == worker_id).unwrap_or(job.worker_order.len()) as u32;
    // Snapshot-serve jobs carry this worker's stripe of the committed
    // manifest: the task streams stored segments instead of producing. A
    // worker past the creation-time order (late registration) gets an
    // empty slice and serves immediate EOS — no duplicated segments.
    let snapshot_manifest = (job.snapshot_serve)
        .then(|| meta.snapshots.get(&job.dataset_id))
        .flatten()
        .map(|m| {
            partition_manifest(m, worker_index as usize, job.worker_order.len().max(1))
        });
    let mut consumers: Vec<u64> = job.clients.iter().copied().collect();
    consumers.sort_unstable();
    // Round residues this worker currently holds the lease for — its
    // own index at creation; possibly fewer (revived worker whose
    // residues moved away) or more (survivor that adopted a failed
    // owner's) later.
    let owned_residues: Vec<u32> = job
        .residue_owners
        .iter()
        .enumerate()
        .filter(|(_, &w)| w == worker_id)
        .map(|(i, _)| i as u32)
        .collect();
    TaskDef {
        job_id,
        dataset_id: job.dataset_id,
        graph: graph.clone(),
        sharding: job.sharding,
        mode: job.mode,
        num_consumers: job.num_consumers,
        static_shards,
        worker_index,
        num_workers: job.worker_order.len().max(1) as u32,
        consumers,
        owned_residues,
        // Materialization floor: a worker (re-)receiving this task
        // mid-epoch starts labeling at the minimum round any consumer
        // still needs, not at round 0.
        start_round: job.floor(),
        // This dispatcher always sends the authoritative lease view: an
        // empty `owned_residues` means leaseless, never "assume your own
        // worker_index" (the pre-lease fallback).
        has_lease_view: true,
        // Full membership-epoch history, so a (re)started worker keys
        // every buffered round at the width its epoch dictates.
        width_epochs: job.width_epochs.clone(),
        snapshot_manifest,
    }
}

/// Pick the live job a `sharing: auto` request may attach to: same
/// pipeline fingerprint (= dataset id) and identical processing settings,
/// itself created with `sharing: auto`. Lowest job id wins so concurrent
/// requests converge on one production. Auto sharing is independent-mode
/// only — coordinated consumers occupy fixed slots and group explicitly
/// via job names.
fn find_shareable_job(meta: &Meta, req: &GetOrCreateJobReq) -> Option<u64> {
    if req.sharing != SharingMode::Auto || req.mode != ProcessingMode::Independent {
        return None;
    }
    meta.jobs
        .iter()
        .filter(|(_, j)| {
            !j.finished
                && j.dataset_id == req.dataset_id
                && j.sharing == SharingMode::Auto
                && j.sharding == req.sharding
                && j.mode == req.mode
                && j.num_consumers == req.num_consumers
        })
        .map(|(&id, _)| id)
        .min()
}

/// Attach `client_id` to the live job `job_id`: under one lock,
/// re-validating that the job is still live, journal the join, record
/// the membership, and queue a consumer update for every worker running
/// the job so the multi-consumer cache registers the new cursor.
///
/// Returns `None` if the job finished between the caller's lookup and
/// this call (its last client released in the gap): the caller must fall
/// back to creating a fresh job instead of joining a dead one, which
/// would silently end the new client's stream with zero elements —
/// nothing is journaled on that path.
fn attach_client(
    state: &Arc<State>,
    job_id: u64,
    client_id: u64,
    auto: bool,
) -> ServiceResult<Option<GetOrCreateJobResp>> {
    let mut meta = state.meta.lock().unwrap();
    let snapshot = match meta.jobs.get_mut(&job_id) {
        Some(job) if !job.finished => {
            // Journal + apply inside the same `meta` section (see
            // `journal_append`'s invariant), write-ahead first.
            journal_append(state, &JournalRecord::ClientJoined { job_id, client_id })?;
            job.clients.insert(client_id);
            job.snapshot_serve
        }
        _ => return Ok(None), // finished in the gap: caller re-creates
    };
    let update = ConsumerUpdate { job_id, client_id };
    let mut push_addrs = Vec::new();
    for w in meta.workers.values_mut() {
        if w.assigned.contains(&job_id) {
            w.pending_attach.push(update.clone());
            if w.alive {
                push_addrs.push(w.addr.clone());
            }
        }
    }
    drop(meta);
    // Synchronous push: register the new cursor on every worker *before*
    // answering the client, so its first fetch cannot race the eager
    // window eviction of the cursors already running. Best-effort — the
    // heartbeat queue above re-delivers (idempotently) if a push fails.
    push_consumer_updates(state, &push_addrs, vec![update], Vec::new());
    // Fingerprint-matched (auto) attaches and explicit named-job joins
    // are separate signals: only the former measures §3.5 auto sharing.
    if auto {
        state.metrics.counter("dispatcher/sharing_attaches").inc();
    } else {
        state.metrics.counter("dispatcher/named_job_joins").inc();
    }
    Ok(Some(GetOrCreateJobResp { job_id, client_id, attached: true, snapshot }))
}

/// Best-effort dispatcher -> worker consumer-update push (the heartbeat
/// queues remain the reliable, idempotent fallback).
fn push_consumer_updates(
    state: &Arc<State>,
    addrs: &[String],
    attached: Vec<ConsumerUpdate>,
    released: Vec<ConsumerUpdate>,
) {
    if addrs.is_empty() || (attached.is_empty() && released.is_empty()) {
        return;
    }
    let req = UpdateConsumersReq { attached, released };
    for addr in addrs {
        let r: Result<UpdateConsumersResp, _> = crate::rpc::call_typed(
            &state.pool,
            addr,
            worker_methods::UPDATE_CONSUMERS,
            &req,
            Duration::from_secs(1),
        );
        match r {
            Ok(_) => state.metrics.counter("dispatcher/consumer_pushes").inc(),
            Err(_) => state.metrics.counter("dispatcher/consumer_push_failures").inc(),
        }
    }
}

fn get_or_create_job(state: &Arc<State>, req: GetOrCreateJobReq) -> ServiceResult<GetOrCreateJobResp> {
    let mut meta = state.meta.lock().unwrap();
    if !meta.datasets.contains_key(&req.dataset_id) {
        return Err(ServiceError::UnknownDataset(req.dataset_id));
    }

    // Named job reuse: explicitly grouped clients attach to the same job.
    if !req.job_name.is_empty() {
        if let Some(&job_id) = meta.named_jobs.get(&(req.dataset_id, req.job_name.clone())) {
            if meta.jobs.get(&job_id).map(|j| !j.finished).unwrap_or(false) {
                let client_id = meta.next_client_id;
                meta.next_client_id += 1;
                drop(meta);
                if let Some(resp) = attach_client(state, job_id, client_id, false)? {
                    return Ok(resp);
                }
                // Job finished in the gap: create a fresh one below.
                meta = state.meta.lock().unwrap();
            }
        }
    } else if let Some(job_id) = find_shareable_job(&meta, &req) {
        // Ephemeral sharing (§3.5): a live job is already producing this
        // exact pipeline — attach instead of creating a k-th production.
        let client_id = meta.next_client_id;
        meta.next_client_id += 1;
        drop(meta);
        if let Some(resp) = attach_client(state, job_id, client_id, true)? {
            return Ok(resp);
        }
        // Job finished in the gap: create a fresh one below.
        meta = state.meta.lock().unwrap();
    }

    // Admission control: shed job *creation* (not attaches — joining an
    // existing production adds no new pipeline) once the unfinished-job
    // budget is spent. Shed requests carry a retry hint the client
    // honors with jittered backoff; nothing is journaled for them.
    if state.cfg.admission_max_jobs > 0 {
        let active = meta.jobs.values().filter(|j| !j.finished).count();
        if active >= state.cfg.admission_max_jobs {
            drop(meta);
            state.metrics.counter("dispatcher/jobs_shed").inc();
            return Err(ServiceError::Overloaded {
                retry_after_ms: state.cfg.admission_retry_ms,
            });
        }
    }

    let job_id = meta.next_job_id;
    meta.next_job_id += 1;
    let client_id = meta.next_client_id;
    meta.next_client_id += 1;

    // Fingerprint-keyed snapshot reuse: no live production to share, but
    // an identical pipeline (same dataset fingerprint) already committed
    // a full epoch to the store — create the job in snapshot-serve mode
    // so workers stream stored segments instead of re-running the
    // pipeline. Opt-in via `sharing: auto`, unnamed independent jobs
    // only (named jobs and coordinated reads pin live semantics).
    let snapshot_serve = req.job_name.is_empty()
        && req.sharing == SharingMode::Auto
        && req.mode == ProcessingMode::Independent
        && meta.snapshots.contains_key(&req.dataset_id);

    let graph = meta.datasets.get(&req.dataset_id).unwrap().clone();
    let num_shards = graph_num_shards(&graph);
    let tracker = matches!(req.sharding, ShardingPolicy::Dynamic)
        .then(|| Arc::new(SplitTracker::new(num_shards, state.cfg.split_seed ^ job_id)));

    // Fix the worker order now (coordinated reads round-robin is stable).
    // Draining workers are on their way out and take no new jobs.
    let mut worker_order: Vec<u64> = meta
        .workers
        .iter()
        .filter(|(_, w)| w.alive && !w.draining)
        .map(|(&id, _)| id)
        .collect();
    worker_order.sort_unstable();

    let job = JobState {
        dataset_id: req.dataset_id,
        job_name: req.job_name.clone(),
        sharding: req.sharding,
        mode: req.mode,
        num_consumers: req.num_consumers,
        sharing: req.sharing,
        tracker,
        clients: HashSet::from([client_id]),
        finished: false,
        worker_order: worker_order.clone(),
        // Round leases start with the fixed round-robin assignment.
        residue_owners: worker_order.clone(),
        client_rounds: HashMap::new(),
        pending_handoffs: Vec::new(),
        client_stalls: HashMap::new(),
        width_epochs: vec![WidthEpoch {
            epoch: 0,
            barrier_round: 0,
            num_consumers: req.num_consumers,
        }],
        spill_manifests: HashMap::new(),
        snapshot_committed: false,
        snapshot_serve,
    };

    // Write-ahead, *before* publication: a concurrent sharing attach can
    // only discover this job once it appears in `meta.jobs`, and
    // attach_client journals its ClientJoined immediately — so CreateJob
    // must already be durable or replay would drop that join (and the
    // job would later be GC'd with the attached client still streaming).
    // The journal has its own lock and never takes `meta`, so appending
    // while holding `meta` cannot deadlock.
    journal_append(
        state,
        &JournalRecord::CreateJob {
            job_id,
            dataset_id: req.dataset_id,
            job_name: req.job_name.clone(),
            sharding: req.sharding,
            mode: req.mode,
            num_consumers: req.num_consumers,
            sharing: req.sharing,
            // The fixed coordinated worker order rides the journal so a
            // restarted dispatcher rebuilds the round-lease table
            // (RoundLeaseChanged records then replay over this baseline).
            worker_order: worker_order.clone(),
            snapshot: snapshot_serve,
        },
    )?;
    journal_append(state, &JournalRecord::ClientJoined { job_id, client_id })?;

    // Publish: build per-worker tasks and expose the job.
    let static_shards = if matches!(req.sharding, ShardingPolicy::Static) {
        static_assignment(num_shards, worker_order.len().max(1))
    } else {
        vec![Vec::new(); worker_order.len().max(1)]
    };
    let tasks: Vec<(u64, TaskDef)> = worker_order
        .iter()
        .enumerate()
        .map(|(i, &wid)| (wid, make_task(&meta, job_id, &job, &graph, wid, static_shards[i].clone())))
        .collect();

    meta.jobs.insert(job_id, job);
    if !req.job_name.is_empty() {
        meta.named_jobs.insert((req.dataset_id, req.job_name.clone()), job_id);
    }
    for (wid, task) in tasks {
        if let Some(w) = meta.workers.get_mut(&wid) {
            w.pending_tasks.push(task);
            w.assigned.insert(job_id);
        }
    }
    drop(meta);

    state.metrics.counter("dispatcher/jobs_created").inc();
    if snapshot_serve {
        state.metrics.counter("dispatcher/snapshot_attaches").inc();
    }
    Ok(GetOrCreateJobResp { job_id, client_id, attached: false, snapshot: snapshot_serve })
}

fn client_heartbeat(state: &Arc<State>, req: ClientHeartbeatReq) -> ServiceResult<ClientHeartbeatResp> {
    let mut meta = state.meta.lock().unwrap();
    let meta = &mut *meta;
    let job = meta.jobs.get_mut(&req.job_id).ok_or(ServiceError::UnknownJob(req.job_id))?;
    // Coordinated consumers report the next round they will fetch: the
    // job-wide minimum is the floor for round-lease reassignments.
    // `u64::MAX` is the "progress unknown" sentinel a just-started
    // consumer sends before it has fast-forwarded to the job floor — it
    // must not enter the minimum (a fresh attacher would otherwise drag
    // the floor to 0 with its first heartbeat).
    if job.mode == ProcessingMode::Coordinated && req.next_round != u64::MAX {
        job.client_rounds.insert(req.consumer_index, (req.next_round, Instant::now()));
    }
    // Input-stall signal for the closed-loop autoscaler: the fraction of
    // this trainer's next() calls since its last heartbeat that found no
    // element ready, in thousandths.
    job.client_stalls.insert(req.client_id, (req.stall_fraction_milli, Instant::now()));
    // Workers serving this job, in the job's fixed coordinated order
    // first, then any later joiners. Draining workers are excluded: new
    // consumer routing stops at drain start (existing round leases still
    // resolve through `round_owner_addrs` below until handed off).
    let mut addrs = Vec::new();
    for wid in &job.worker_order {
        if let Some(w) = meta.workers.get(wid) {
            if w.alive && !w.draining {
                addrs.push(w.addr.clone());
            }
        }
    }
    for (wid, w) in meta.workers.iter() {
        if w.alive
            && !w.draining
            && w.assigned.contains(&req.job_id)
            && !job.worker_order.contains(wid)
        {
            addrs.push(w.addr.clone());
        }
    }
    // Residue-indexed round-lease holders: clients route round `r` to
    // `round_owner_addrs[r % len]`, which tracks reassignments (the
    // plain `worker_addrs` list shrinks when an owner dies, which would
    // silently remap every round).
    let round_owner_addrs: Vec<String> = if job.mode == ProcessingMode::Coordinated {
        job.residue_owners
            .iter()
            .map(|wid| meta.workers.get(wid).map(|w| w.addr.clone()).unwrap_or_default())
            .collect()
    } else {
        Vec::new()
    };
    // Slot-scoped fast-forward floor: the requesting consumer's *own*
    // slot's recorded progress — its crashed predecessor's report — or
    // 0 for a slot nobody has reported for. A fresh consumer in a
    // staggered startup therefore is never skipped past rounds still
    // buffered for it, and a replacement resumes exactly where its
    // predecessor stopped (not at the job-wide minimum, which for a
    // non-slowest slot would point at a round this slot already
    // consumed — a terminal protocol error).
    let round_floor = if job.mode == ProcessingMode::Coordinated {
        let slot_floor = job.client_rounds.get(&req.consumer_index).map(|&(r, _)| r).unwrap_or(0);
        // Slot-activation barrier (elastic membership): the earliest
        // barrier of the contiguous suffix of epochs whose width covers
        // this slot. A slot grown into existence mid-job starts
        // fetching at the round its slot first exists — a floor of 0
        // would have it wait forever on rounds keyed before it was
        // born. A slot covered since epoch 0 sees activation 0 (no
        // change); a slot the current epoch shrank away keeps its plain
        // progress floor and drains up to the barrier.
        let mut activation = 0u64;
        for e in job.width_epochs.iter().rev() {
            if e.num_consumers > req.consumer_index {
                activation = e.barrier_round;
            } else {
                break;
            }
        }
        slot_floor.max(activation)
    } else {
        0
    };
    let cur = job.width_epochs.last().copied().unwrap_or(WidthEpoch {
        epoch: 0,
        barrier_round: 0,
        num_consumers: job.num_consumers,
    });
    Ok(ClientHeartbeatResp {
        worker_addrs: addrs,
        job_finished: job.finished,
        round_owner_addrs,
        round_floor,
        membership_epoch: cur.epoch,
        num_consumers: cur.num_consumers,
        width_barrier_round: cur.barrier_round,
    })
}

fn register_worker(state: &Arc<State>, req: RegisterWorkerReq) -> ServiceResult<RegisterWorkerResp> {
    let mut meta = state.meta.lock().unwrap();
    // Re-registration after restart: same address = same logical worker.
    let existing = meta.workers.iter().find(|(_, w)| w.addr == req.addr).map(|(&id, _)| id);
    let worker_id = existing.unwrap_or_else(|| {
        let id = meta.next_worker_id;
        meta.next_worker_id += 1;
        id
    });

    // Stateless worker recovery (§3.4): hand it tasks for every active job.
    let mut tasks = Vec::new();
    let job_ids: Vec<u64> = meta.jobs.iter().filter(|(_, j)| !j.finished).map(|(&id, _)| id).collect();
    for jid in &job_ids {
        let job = meta.jobs.get(jid).unwrap();
        let graph = meta.datasets.get(&job.dataset_id).cloned().unwrap_or_default();
        let task = make_task(&meta, *jid, job, &graph, worker_id, Vec::new());
        tasks.push(task);
    }
    let assigned: HashSet<u64> = job_ids.iter().copied().collect();

    // A re-registering worker comes back state-free: any previous drain
    // is over (WorkerInfo::new defaults to not draining). Journal the
    // exit so a replayed drain flag does not survive the re-admission.
    // Both records land before the table mutation, under the same
    // `meta` section (see `journal_append`'s invariant).
    let was_draining =
        existing.is_some() && meta.workers.get(&worker_id).map(|w| w.draining).unwrap_or(false);
    if was_draining {
        journal_append(state, &JournalRecord::WorkerDrainChanged { worker_id, draining: false })?;
    }
    if existing.is_none() {
        journal_append(
            state,
            &JournalRecord::RegisterWorker { worker_id, addr: req.addr.clone() },
        )?;
    }
    meta.workers.insert(worker_id, WorkerInfo::new(req.addr, Instant::now(), true, assigned));
    drop(meta);

    if existing.is_none() {
        state.metrics.counter("dispatcher/workers_registered").inc();
    }
    Ok(RegisterWorkerResp { worker_id, tasks })
}

/// Ingest one worker's completed spill manifests: record each against
/// its job and, once every worker in the job's creation-time order has
/// reported, journal the merged snapshot and publish it under the
/// pipeline fingerprint (§ spill tier & snapshots). Returns the job ids
/// whose manifests the worker may stop re-reporting — the commit is
/// durable (or already was), so the ack cannot lose a snapshot.
fn ingest_spill_manifests(
    state: &Arc<State>,
    meta: &mut Meta,
    worker_id: u64,
    manifests: &[SpillManifest],
) -> ServiceResult<Vec<u64>> {
    let mut acks = Vec::new();
    // Split borrow: the job table and the snapshot index are touched in
    // the same commit step.
    let Meta { jobs, snapshots, .. } = meta;
    for man in manifests {
        if !man.complete {
            continue; // defensive: workers only report complete manifests
        }
        let Some(job) = jobs.get_mut(&man.job_id) else {
            // Unknown (GC'd / pre-restart) job: nothing to commit against,
            // ack so the worker stops re-reporting.
            acks.push(man.job_id);
            continue;
        };
        if job.snapshot_committed || job.snapshot_serve {
            acks.push(man.job_id);
            continue;
        }
        if !job.worker_order.contains(&worker_id) {
            // Late-registered worker outside the creation-time order: its
            // task never produced this job's stripe, so its (empty)
            // manifest is not part of the commit gate.
            acks.push(man.job_id);
            continue;
        }
        job.spill_manifests.insert(worker_id, man.clone());
        let all_reported =
            job.worker_order.iter().all(|w| job.spill_manifests.contains_key(w));
        if !all_reported {
            continue; // unacked: the worker re-reports until the commit
        }
        let fingerprint = man.fingerprint;
        let parts: Vec<SpillManifest> = job
            .worker_order
            .iter()
            .map(|w| job.spill_manifests[w].clone())
            .collect();
        let epoch = snapshots.get(&fingerprint).map(|m| m.epoch + 1).unwrap_or(0);
        let merged = merge_manifests(fingerprint, man.job_id, epoch, &parts);
        // Superseded-snapshot GC: this commit replaces the fingerprint's
        // previous snapshot, whose segments live under the *old* job's
        // `spill/job-{id}/*` keys — journal the GC first (so replay
        // re-issues the idempotent deletes), then drop the objects.
        let superseded = snapshots
            .get(&fingerprint)
            .map(|old| old.job_id)
            .filter(|&old_job| old_job != man.job_id);
        // Durable before published (and before the ack): a crash after
        // the append replays the commit; a crash before it leaves the
        // workers re-reporting and the commit redone.
        journal_append(state, &JournalRecord::SnapshotCommitted {
            fingerprint,
            epoch,
            manifest: merged.clone(),
        })?;
        if let Some(old_job) = superseded {
            journal_append(state, &JournalRecord::SpillSnapshotGced { job_id: old_job })?;
            if let Some(store) = &state.cfg.store {
                store.delete(&data_key(old_job));
                store.delete(&manifest_key(old_job));
            }
            state.metrics.counter("dispatcher/spill_snapshots_gced").inc();
        }
        job.snapshot_committed = true;
        snapshots.insert(fingerprint, merged);
        state.metrics.counter("dispatcher/snapshots_committed").inc();
        acks.push(man.job_id);
    }
    Ok(acks)
}

fn worker_heartbeat(state: &Arc<State>, req: WorkerHeartbeatReq) -> ServiceResult<WorkerHeartbeatResp> {
    let mut meta = state.meta.lock().unwrap();
    if !meta.workers.contains_key(&req.worker_id) {
        return Err(ServiceError::UnknownWorker(req.worker_id));
    }
    // Phase 2 of any in-flight lease handoffs runs *before* the response
    // is assembled: an acked revocation must not be re-delivered below,
    // and the gainer's grant queues here so it rides the gainer's very
    // next heartbeat.
    complete_lease_handoffs(state, &mut meta, req.worker_id, &req.revoke_acks)?;
    let finished_jobs: Vec<u64> =
        meta.jobs.iter().filter(|(_, j)| j.finished).map(|(&id, _)| id).collect();
    // The worker's own task report is authoritative for live jobs: after
    // a dispatcher restart, replayed workers come back with an empty
    // `assigned` set even though they kept running their tasks (§3.4
    // stateless recovery is worker-driven). Re-learning assignments here
    // keeps client heartbeats and sharing attach/detach updates flowing
    // to those workers.
    let live_reported: Vec<u64> = req
        .active_tasks
        .iter()
        .copied()
        .filter(|t| meta.jobs.get(t).map(|j| !j.finished).unwrap_or(false))
        .collect();
    let w = meta.workers.get_mut(&req.worker_id).ok_or(ServiceError::UnknownWorker(req.worker_id))?;
    let was_dead = !w.alive;
    // First heartbeat after a journal-backed restore: lease-view
    // deliveries queued by the previous dispatcher incarnation died with
    // its in-memory heartbeat queues, so this heartbeat must re-push the
    // authoritative view (below) or a granted-but-undelivered residue
    // would answer WrongWorker forever.
    let was_unconfirmed = !w.confirmed;
    w.last_heartbeat = Instant::now();
    w.alive = true;
    // Evidence from the current incarnation: re-balance may now trust it.
    w.confirmed = true;
    if was_dead {
        // Revival timestamp: the re-balance hysteresis clock starts now.
        w.alive_since = w.last_heartbeat;
    }
    w.assigned.extend(live_reported);
    w.last_cpu_milli = req.cpu_util_milli;
    w.drain_ready = req.drain_ready;
    let draining = w.draining;
    // Cloned, not taken: revocations are re-delivered on every heartbeat
    // until the worker acks them (at-least-once; applying a revocation
    // twice is a no-op on the worker).
    let round_revocations = w.pending_revocations.clone();
    let new_tasks: Vec<TaskDef> = std::mem::take(&mut w.pending_tasks);
    let attached_clients = std::mem::take(&mut w.pending_attach);
    let released_clients = std::mem::take(&mut w.pending_detach);
    let mut round_assignments = std::mem::take(&mut w.pending_rounds);
    let mut width_updates = std::mem::take(&mut w.pending_widths);
    let removed: Vec<u64> =
        req.active_tasks.iter().copied().filter(|t| finished_jobs.contains(t)).collect();
    for t in &removed {
        w.assigned.remove(t);
    }
    if was_dead || was_unconfirmed {
        // A worker back from the dead may still believe it owns round
        // residues that were leased to survivors while it was silent:
        // hand it the authoritative lease view for every coordinated
        // job, so a zombie owner stops materializing (and serving)
        // rounds whose lease moved — split-brain rounds would break the
        // §3.6 same-batch-per-round guarantee. The same push runs on the
        // first heartbeat after a dispatcher restart (`was_unconfirmed`):
        // it replaces any lease-view delivery the previous incarnation
        // queued but never delivered.
        for (&job_id, job) in meta.jobs.iter() {
            if job.finished
                || job.mode != ProcessingMode::Coordinated
                || job.residue_owners.is_empty()
            {
                continue;
            }
            let owned_residues: Vec<u32> = job
                .residue_owners
                .iter()
                .enumerate()
                .filter(|(_, &o)| o == req.worker_id)
                .map(|(i, _)| i as u32)
                .collect();
            // Floor at the minimum round any consumer still needs: a
            // worker that kept running keeps its own progress (retained
            // residues ignore the floor), while one that really
            // restarted starts labeling where consumers are, not at 0.
            let start_round = job.floor();
            round_assignments.push(RoundAssignment { job_id, owned_residues, start_round });
            // Same delivery guarantee for the membership-epoch schedule:
            // a width change queued for (or applied by) the worker's
            // previous incarnation may be gone — re-push the full
            // schedule (idempotent application) whenever it is non
            // -trivial.
            if job.width_epochs.len() > 1 {
                width_updates.push(ConsumerSetUpdate {
                    job_id,
                    width_epochs: job.width_epochs.clone(),
                });
            }
        }
    }
    state
        .metrics
        .gauge("dispatcher/last_worker_cpu_milli")
        .set(req.cpu_util_milli as i64);
    let manifest_acks =
        ingest_spill_manifests(state, &mut meta, req.worker_id, &req.spill_manifests)?;
    Ok(WorkerHeartbeatResp {
        new_tasks,
        removed_tasks: removed,
        attached_clients,
        released_clients,
        round_assignments,
        width_updates,
        manifest_acks,
        round_revocations,
        drain: draining,
    })
}

/// Elastic consumer membership (§3.6 extension): append a new
/// membership epoch to a coordinated job. The barrier is the first
/// round no live consumer slot has fetched yet — `max(` every slot's
/// reported progress, the previous epoch's barrier, the job floor `)` —
/// so no round already shaped (or in flight) is ever re-keyed, and
/// barriers stay monotone across epochs. The `ConsumerSetChanged`
/// record is journaled *before* the schedule is published to workers or
/// acknowledged, so a restarted dispatcher never replays a narrower
/// history than the one workers re-keyed at. Idempotent: asking for the
/// current width answers the current `(epoch, barrier)` unchanged.
fn set_job_consumers(state: &Arc<State>, req: SetJobConsumersReq) -> ServiceResult<SetJobConsumersResp> {
    if req.num_consumers == 0 {
        return Err(ServiceError::Other("set_job_consumers: num_consumers must be >= 1".into()));
    }
    let mut meta = state.meta.lock().unwrap();
    let meta = &mut *meta;
    let job = meta.jobs.get_mut(&req.job_id).ok_or(ServiceError::UnknownJob(req.job_id))?;
    if job.mode != ProcessingMode::Coordinated {
        return Err(ServiceError::Other(format!(
            "set_job_consumers: job {} is not coordinated",
            req.job_id
        )));
    }
    let cur = *job.width_epochs.last().expect("epoch schedule never empty");
    if cur.num_consumers == req.num_consumers {
        return Ok(SetJobConsumersResp { epoch: cur.epoch, barrier_round: cur.barrier_round });
    }
    // `client_rounds` never holds the u64::MAX "unknown" sentinel (the
    // heartbeat handler filters it), so the max is real slot progress.
    let progress_max = job.client_rounds.values().map(|&(r, _)| r).max().unwrap_or(0);
    let barrier_round = progress_max.max(cur.barrier_round).max(job.floor());
    let epoch = cur.epoch + 1;
    journal_append(
        state,
        &JournalRecord::ConsumerSetChanged {
            job_id: req.job_id,
            epoch,
            barrier_round,
            num_consumers: req.num_consumers,
        },
    )?;
    job.width_epochs.push(WidthEpoch { epoch, barrier_round, num_consumers: req.num_consumers });
    job.num_consumers = req.num_consumers;
    let update = ConsumerSetUpdate { job_id: req.job_id, width_epochs: job.width_epochs.clone() };
    for w in meta.workers.values_mut() {
        if w.alive && w.assigned.contains(&req.job_id) {
            w.pending_widths.push(update.clone());
        }
    }
    state.metrics.counter("dispatcher/consumer_set_changes").inc();
    Ok(SetJobConsumersResp { epoch, barrier_round })
}

fn get_split(state: &Arc<State>, req: GetSplitReq) -> ServiceResult<GetSplitResp> {
    let meta = state.meta.lock().unwrap();
    let job = meta.jobs.get(&req.job_id).ok_or(ServiceError::UnknownJob(req.job_id))?;
    let split = match &job.tracker {
        Some(t) => t.next_split(req.worker_id),
        None => None, // OFF/static: workers do not ask
    };
    Ok(GetSplitResp { split })
}

fn release_job(state: &Arc<State>, req: ReleaseJobReq) -> ServiceResult<ReleaseJobResp> {
    let mut finished = false;
    let mut push_addrs = Vec::new();
    {
        let mut meta = state.meta.lock().unwrap();
        let job = meta.jobs.get_mut(&req.job_id).ok_or(ServiceError::UnknownJob(req.job_id))?;
        // Write-ahead under the same `meta` section (see
        // `journal_append`'s invariant): the release — and, when it
        // empties the membership, the finish — are journaled before the
        // tables they describe change.
        journal_append(
            state,
            &JournalRecord::ClientReleased { job_id: req.job_id, client_id: req.client_id },
        )?;
        if !job.finished && job.clients.iter().all(|c| *c == req.client_id) {
            journal_append(state, &JournalRecord::JobFinished { job_id: req.job_id })?;
        }
        job.clients.remove(&req.client_id);
        // Slot progress (keyed by consumer index, which the release does
        // not carry) is left to the tick() lease pruning: a re-occupied
        // slot overwrites it, a finished job never reads it again.
        if job.clients.is_empty() && !job.finished {
            job.finished = true;
            finished = true;
            let name_key = (job.dataset_id, job.job_name.clone());
            if !name_key.1.is_empty() {
                meta.named_jobs.remove(&name_key);
            }
        }
        // Tell workers to drop the departed consumer's cursor so it never
        // pins the shared sliding window (§3.5); pointless when the whole
        // job is finished — workers then drop the task wholesale.
        if !finished {
            let update = ConsumerUpdate { job_id: req.job_id, client_id: req.client_id };
            for w in meta.workers.values_mut() {
                if w.assigned.contains(&req.job_id) {
                    w.pending_detach.push(update.clone());
                    if w.alive {
                        push_addrs.push(w.addr.clone());
                    }
                }
            }
        }
    }
    if !finished {
        // Synchronous push (best-effort): a departed laggard stops
        // pinning the eagerly-evicted window immediately, not a
        // heartbeat later.
        let update = ConsumerUpdate { job_id: req.job_id, client_id: req.client_id };
        push_consumer_updates(state, &push_addrs, Vec::new(), vec![update]);
    }
    if finished {
        state.metrics.counter("dispatcher/jobs_finished").inc();
    }
    Ok(ReleaseJobResp { released: true })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::graph::PipelineBuilder;
    use crate::rpc::{call_typed, Pool};

    fn disp() -> (Dispatcher, Pool, String) {
        let d = Dispatcher::start("127.0.0.1:0", DispatcherConfig::default()).unwrap();
        let addr = d.addr();
        (d, Pool::with_defaults(), addr)
    }

    fn timeout() -> Duration {
        Duration::from_secs(5)
    }

    fn register_range_dataset(pool: &Pool, addr: &str) -> u64 {
        let graph = PipelineBuilder::source_range(10).batch(2).build();
        let resp: RegisterDatasetResp = call_typed(
            pool,
            addr,
            dispatcher_methods::REGISTER_DATASET,
            &RegisterDatasetReq { graph, udf_digests: vec![] },
            timeout(),
        )
        .unwrap();
        resp.dataset_id
    }

    fn job_req(dataset_id: u64, job_name: &str, sharing: SharingMode) -> GetOrCreateJobReq {
        GetOrCreateJobReq {
            dataset_id,
            job_name: job_name.into(),
            sharding: ShardingPolicy::Off,
            mode: ProcessingMode::Independent,
            num_consumers: 0,
            sharing,
        }
    }

    #[test]
    fn dataset_registration_is_idempotent() {
        let (_d, pool, addr) = disp();
        let a = register_range_dataset(&pool, &addr);
        let b = register_range_dataset(&pool, &addr);
        assert_eq!(a, b, "same graph -> same fingerprint id");
    }

    #[test]
    fn udf_body_digest_separates_dataset_ids() {
        let (_d, pool, addr) = disp();
        let graph = PipelineBuilder::source_range(10).map("custom.op").batch(2).build();
        let register = |digest: Option<u64>| -> RegisterDatasetResp {
            let udf_digests = digest
                .map(|d| vec![UdfDigest { name: "custom.op".into(), digest: d }])
                .unwrap_or_default();
            call_typed(
                &pool,
                &addr,
                dispatcher_methods::REGISTER_DATASET,
                &RegisterDatasetReq { graph: graph.clone(), udf_digests },
                timeout(),
            )
            .unwrap()
        };
        let v1 = register(Some(1));
        let v2 = register(Some(2));
        let plain = register(None);
        assert_ne!(v1.dataset_id, v2.dataset_id, "different UDF bodies never share");
        assert_ne!(v1.dataset_id, plain.dataset_id);
        assert_eq!(register(Some(1)).dataset_id, v1.dataset_id, "digest registration idempotent");
        assert_eq!(v1.fingerprint.len(), 32, "full fingerprint carried in the response");
    }

    #[test]
    fn job_lifecycle_and_worker_discovery() {
        let (_d, pool, addr) = disp();
        let ds = register_range_dataset(&pool, &addr);

        // Register a worker first so the job picks it up.
        let w: RegisterWorkerResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::REGISTER_WORKER,
            &RegisterWorkerReq { addr: "127.0.0.1:7001".into() },
            timeout(),
        )
        .unwrap();
        assert!(w.tasks.is_empty(), "no jobs yet");

        let j: GetOrCreateJobResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::GET_OR_CREATE_JOB,
            &job_req(ds, "", SharingMode::Off),
            timeout(),
        )
        .unwrap();
        assert!(!j.attached);

        // Worker heartbeat receives the new task.
        let hb: WorkerHeartbeatResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::WORKER_HEARTBEAT,
            &WorkerHeartbeatReq {
                worker_id: w.worker_id,
                active_tasks: vec![],
                cpu_util_milli: 0,
                spill_manifests: vec![],
                revoke_acks: vec![],
                drain_ready: false,
            },
            timeout(),
        )
        .unwrap();
        assert_eq!(hb.new_tasks.len(), 1);
        assert_eq!(hb.new_tasks[0].job_id, j.job_id);

        // Client heartbeat lists the worker.
        let ch: ClientHeartbeatResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::CLIENT_HEARTBEAT,
            &ClientHeartbeatReq {
                job_id: j.job_id,
                client_id: j.client_id,
                next_round: 0,
                consumer_index: 0,
                stall_fraction_milli: 0,
            },
            timeout(),
        )
        .unwrap();
        assert_eq!(ch.worker_addrs, vec!["127.0.0.1:7001".to_string()]);
        assert!(!ch.job_finished);

        // Release -> job finished -> heartbeat reports removal.
        let _: ReleaseJobResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::RELEASE_JOB,
            &ReleaseJobReq { job_id: j.job_id, client_id: j.client_id },
            timeout(),
        )
        .unwrap();
        let hb2: WorkerHeartbeatResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::WORKER_HEARTBEAT,
            &WorkerHeartbeatReq {
                worker_id: w.worker_id,
                active_tasks: vec![j.job_id],
                cpu_util_milli: 0,
                spill_manifests: vec![],
                revoke_acks: vec![],
                drain_ready: false,
            },
            timeout(),
        )
        .unwrap();
        assert_eq!(hb2.removed_tasks, vec![j.job_id]);
    }

    #[test]
    fn named_jobs_are_shared() {
        let (d, pool, addr) = disp();
        let ds = register_range_dataset(&pool, &addr);
        let req = job_req(ds, "hp", SharingMode::Off);
        let a: GetOrCreateJobResp =
            call_typed(&pool, &addr, dispatcher_methods::GET_OR_CREATE_JOB, &req, timeout()).unwrap();
        let b: GetOrCreateJobResp =
            call_typed(&pool, &addr, dispatcher_methods::GET_OR_CREATE_JOB, &req, timeout()).unwrap();
        assert_eq!(a.job_id, b.job_id, "same name attaches to the same job");
        assert_ne!(a.client_id, b.client_id);
        assert!(!a.attached && b.attached);
        // Explicit grouping is not the §3.5 auto-sharing signal.
        assert_eq!(d.metrics().counter("dispatcher/named_job_joins").get(), 1);
        assert_eq!(d.metrics().counter("dispatcher/sharing_attaches").get(), 0);
    }

    #[test]
    fn auto_sharing_attaches_by_fingerprint() {
        let (d, pool, addr) = disp();
        let ds = register_range_dataset(&pool, &addr);
        let a: GetOrCreateJobResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::GET_OR_CREATE_JOB,
            &job_req(ds, "", SharingMode::Auto),
            timeout(),
        )
        .unwrap();
        // Anonymous request over the same pipeline fingerprint attaches.
        let b: GetOrCreateJobResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::GET_OR_CREATE_JOB,
            &job_req(ds, "", SharingMode::Auto),
            timeout(),
        )
        .unwrap();
        assert_eq!(a.job_id, b.job_id, "same fingerprint shares the production");
        assert!(!a.attached && b.attached);
        assert_eq!(d.job_clients(a.job_id), 2);
        // Incompatible settings (different sharding) do NOT share.
        let mut other = job_req(ds, "", SharingMode::Auto);
        other.sharding = ShardingPolicy::Dynamic;
        let c: GetOrCreateJobResp =
            call_typed(&pool, &addr, dispatcher_methods::GET_OR_CREATE_JOB, &other, timeout()).unwrap();
        assert_ne!(c.job_id, a.job_id, "sharding mismatch is not compatible");
        assert_eq!(d.metrics().counter("dispatcher/sharing_attaches").get(), 1);
    }

    #[test]
    fn sharing_opt_out_creates_dedicated_jobs() {
        let (_d, pool, addr) = disp();
        let ds = register_range_dataset(&pool, &addr);
        let a: GetOrCreateJobResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::GET_OR_CREATE_JOB,
            &job_req(ds, "", SharingMode::Off),
            timeout(),
        )
        .unwrap();
        // Opt-out on the new request: never attach.
        let b: GetOrCreateJobResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::GET_OR_CREATE_JOB,
            &job_req(ds, "", SharingMode::Off),
            timeout(),
        )
        .unwrap();
        assert_ne!(a.job_id, b.job_id, "explicit opt-out stays dedicated");
        // Opt-out on the existing job: an Auto request must not join it.
        let c: GetOrCreateJobResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::GET_OR_CREATE_JOB,
            &job_req(ds, "", SharingMode::Auto),
            timeout(),
        )
        .unwrap();
        assert_ne!(c.job_id, a.job_id);
        assert_ne!(c.job_id, b.job_id);
        assert!(!c.attached);
    }

    #[test]
    fn auto_sharing_survives_dispatcher_restart() {
        let dir = std::env::temp_dir().join(format!("tfdatasvc-disp-share-{}", std::process::id()));
        let jpath = dir.join("journal");
        let _ = std::fs::remove_file(&jpath);
        let cfg = DispatcherConfig { journal_path: Some(jpath.clone()), ..Default::default() };

        let (ds, job_id) = {
            let d = Dispatcher::start("127.0.0.1:0", cfg.clone()).unwrap();
            let pool = Pool::with_defaults();
            let addr = d.addr();
            let ds = register_range_dataset(&pool, &addr);
            let j: GetOrCreateJobResp = call_typed(
                &pool,
                &addr,
                dispatcher_methods::GET_OR_CREATE_JOB,
                &job_req(ds, "", SharingMode::Auto),
                timeout(),
            )
            .unwrap();
            (ds, j.job_id)
        };

        // The replayed job is still discoverable by fingerprint: a new
        // anonymous auto client attaches to it instead of re-producing.
        let d2 = Dispatcher::start("127.0.0.1:0", cfg).unwrap();
        let pool = Pool::with_defaults();
        let j2: GetOrCreateJobResp = call_typed(
            &pool,
            &d2.addr(),
            dispatcher_methods::GET_OR_CREATE_JOB,
            &job_req(ds, "", SharingMode::Auto),
            timeout(),
        )
        .unwrap();
        assert_eq!(j2.job_id, job_id, "sharing registry survived the restart");
        assert!(j2.attached);
        std::fs::remove_file(&jpath).ok();
    }

    #[test]
    fn attach_and_release_propagate_consumer_updates_to_workers() {
        let (_d, pool, addr) = disp();
        let ds = register_range_dataset(&pool, &addr);
        let w: RegisterWorkerResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::REGISTER_WORKER,
            &RegisterWorkerReq { addr: "127.0.0.1:7501".into() },
            timeout(),
        )
        .unwrap();
        let a: GetOrCreateJobResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::GET_OR_CREATE_JOB,
            &job_req(ds, "", SharingMode::Auto),
            timeout(),
        )
        .unwrap();
        // Task delivery carries the creating client as initial consumer.
        let hb: WorkerHeartbeatResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::WORKER_HEARTBEAT,
            &WorkerHeartbeatReq {
                worker_id: w.worker_id,
                active_tasks: vec![],
                cpu_util_milli: 0,
                spill_manifests: vec![],
                revoke_acks: vec![],
                drain_ready: false,
            },
            timeout(),
        )
        .unwrap();
        assert_eq!(hb.new_tasks.len(), 1);
        assert_eq!(hb.new_tasks[0].consumers, vec![a.client_id]);

        let b: GetOrCreateJobResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::GET_OR_CREATE_JOB,
            &job_req(ds, "", SharingMode::Auto),
            timeout(),
        )
        .unwrap();
        assert!(b.attached);
        let hb2: WorkerHeartbeatResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::WORKER_HEARTBEAT,
            &WorkerHeartbeatReq {
                worker_id: w.worker_id,
                active_tasks: vec![a.job_id],
                cpu_util_milli: 0,
                spill_manifests: vec![],
                revoke_acks: vec![],
                drain_ready: false,
            },
            timeout(),
        )
        .unwrap();
        assert_eq!(
            hb2.attached_clients,
            vec![ConsumerUpdate { job_id: a.job_id, client_id: b.client_id }]
        );

        // Releasing one of two clients -> detach update, job stays live.
        let _: ReleaseJobResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::RELEASE_JOB,
            &ReleaseJobReq { job_id: a.job_id, client_id: b.client_id },
            timeout(),
        )
        .unwrap();
        let hb3: WorkerHeartbeatResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::WORKER_HEARTBEAT,
            &WorkerHeartbeatReq {
                worker_id: w.worker_id,
                active_tasks: vec![a.job_id],
                cpu_util_milli: 0,
                spill_manifests: vec![],
                revoke_acks: vec![],
                drain_ready: false,
            },
            timeout(),
        )
        .unwrap();
        assert_eq!(
            hb3.released_clients,
            vec![ConsumerUpdate { job_id: a.job_id, client_id: b.client_id }]
        );
        assert!(hb3.removed_tasks.is_empty(), "job still has a live client");
    }

    #[test]
    fn unknown_dataset_rejected() {
        let (_d, pool, addr) = disp();
        let r: Result<GetOrCreateJobResp, _> = call_typed(
            &pool,
            &addr,
            dispatcher_methods::GET_OR_CREATE_JOB,
            &job_req(424242, "", SharingMode::Off),
            timeout(),
        );
        assert!(r.is_err());
    }

    #[test]
    fn dynamic_splits_served_over_rpc() {
        let (_d, pool, addr) = disp();
        let graph = crate::data::graph::PipelineBuilder::source_vision(
            crate::storage::dataset::DatasetSpec {
                prefix: "p".into(),
                shards: (0..5).map(|i| format!("p/s{i}")).collect(),
                samples_per_shard: 1,
                total_samples: 5,
            },
        )
        .build();
        let ds: RegisterDatasetResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::REGISTER_DATASET,
            &RegisterDatasetReq { graph, udf_digests: vec![] },
            timeout(),
        )
        .unwrap();
        let mut req = job_req(ds.dataset_id, "", SharingMode::Off);
        req.sharding = ShardingPolicy::Dynamic;
        let j: GetOrCreateJobResp =
            call_typed(&pool, &addr, dispatcher_methods::GET_OR_CREATE_JOB, &req, timeout())
                .unwrap();
        let mut got = Vec::new();
        loop {
            let s: GetSplitResp = call_typed(
                &pool,
                &addr,
                dispatcher_methods::GET_SPLIT,
                &GetSplitReq { job_id: j.job_id, worker_id: 1 },
                timeout(),
            )
            .unwrap();
            match s.split {
                Some(v) => got.push(v),
                None => break,
            }
        }
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn journal_restores_state_across_restart() {
        let dir = std::env::temp_dir().join(format!("tfdatasvc-disp-{}", std::process::id()));
        let jpath = dir.join("journal");
        let _ = std::fs::remove_file(&jpath);
        let cfg = DispatcherConfig { journal_path: Some(jpath.clone()), ..Default::default() };

        let (ds, job_id) = {
            let d = Dispatcher::start("127.0.0.1:0", cfg.clone()).unwrap();
            let pool = Pool::with_defaults();
            let addr = d.addr();
            let ds = register_range_dataset(&pool, &addr);
            let mut req = job_req(ds, "persistent", SharingMode::Off);
            req.sharding = ShardingPolicy::Dynamic;
            let j: GetOrCreateJobResp =
                call_typed(&pool, &addr, dispatcher_methods::GET_OR_CREATE_JOB, &req, timeout())
                    .unwrap();
            (ds, j.job_id)
        };

        // Restart with the same journal.
        let d2 = Dispatcher::start("127.0.0.1:0", cfg).unwrap();
        let pool = Pool::with_defaults();
        let addr = d2.addr();
        // Named job still resolvable: attaching returns the same job id.
        let mut req = job_req(ds, "persistent", SharingMode::Off);
        req.sharding = ShardingPolicy::Dynamic;
        let j2: GetOrCreateJobResp =
            call_typed(&pool, &addr, dispatcher_methods::GET_OR_CREATE_JOB, &req, timeout())
                .unwrap();
        assert_eq!(j2.job_id, job_id);
        std::fs::remove_file(&jpath).ok();
    }

    #[test]
    fn tick_declares_silent_workers_dead() {
        let cfg = DispatcherConfig { worker_timeout: Duration::from_millis(50), ..Default::default() };
        let d = Dispatcher::start("127.0.0.1:0", cfg).unwrap();
        let pool = Pool::with_defaults();
        let addr = d.addr();
        let _ds = register_range_dataset(&pool, &addr);
        let w: RegisterWorkerResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::REGISTER_WORKER,
            &RegisterWorkerReq { addr: "127.0.0.1:7009".into() },
            timeout(),
        )
        .unwrap();
        assert_eq!(d.num_live_workers(), 1);
        std::thread::sleep(Duration::from_millis(80));
        let dead = d.tick();
        assert_eq!(dead, vec![w.worker_id]);
        assert_eq!(d.num_live_workers(), 0);
        // Worker heartbeats again -> alive again (stateless recovery).
        let _: WorkerHeartbeatResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::WORKER_HEARTBEAT,
            &WorkerHeartbeatReq {
                worker_id: w.worker_id,
                active_tasks: vec![],
                cpu_util_milli: 0,
                spill_manifests: vec![],
                revoke_acks: vec![],
                drain_ready: false,
            },
            timeout(),
        )
        .unwrap();
        assert_eq!(d.num_live_workers(), 1);
    }

    #[test]
    fn set_job_consumers_appends_monotone_epochs() {
        let (d, pool, addr) = disp();
        let ds = register_range_dataset(&pool, &addr);
        let w: RegisterWorkerResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::REGISTER_WORKER,
            &RegisterWorkerReq { addr: "127.0.0.1:7777".into() },
            timeout(),
        )
        .unwrap();
        let mut req = job_req(ds, "elastic", SharingMode::Off);
        req.mode = ProcessingMode::Coordinated;
        req.num_consumers = 2;
        let j: GetOrCreateJobResp =
            call_typed(&pool, &addr, dispatcher_methods::GET_OR_CREATE_JOB, &req, timeout())
                .unwrap();
        // Record slot progress: slot 0 at round 5, slot 1 at round 3.
        for (slot, next) in [(0u32, 5u64), (1, 3)] {
            let _: ClientHeartbeatResp = call_typed(
                &pool,
                &addr,
                dispatcher_methods::CLIENT_HEARTBEAT,
                &ClientHeartbeatReq {
                    job_id: j.job_id,
                    client_id: j.client_id,
                    next_round: next,
                    consumer_index: slot,
                    stall_fraction_milli: 0,
                },
                timeout(),
            )
            .unwrap();
        }
        // Grow 2 -> 3: the barrier is the first round no slot fetched yet.
        let r: SetJobConsumersResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::SET_JOB_CONSUMERS,
            &SetJobConsumersReq { job_id: j.job_id, num_consumers: 3 },
            timeout(),
        )
        .unwrap();
        assert_eq!((r.epoch, r.barrier_round), (1, 5));
        // Idempotent: asking for the current width changes nothing.
        assert_eq!(d.set_job_consumers(j.job_id, 3).unwrap(), (1, 5));
        // A fresh grown slot's heartbeat floor fast-forwards to its
        // activation barrier (its slot does not exist before round 5).
        let hb: ClientHeartbeatResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::CLIENT_HEARTBEAT,
            &ClientHeartbeatReq {
                job_id: j.job_id,
                client_id: j.client_id,
                next_round: u64::MAX,
                consumer_index: 2,
                stall_fraction_milli: 0,
            },
            timeout(),
        )
        .unwrap();
        assert_eq!(hb.round_floor, 5, "grown slot activates at its barrier");
        assert_eq!((hb.membership_epoch, hb.num_consumers, hb.width_barrier_round), (1, 3, 5));
        // Shrink back 3 -> 2: barriers stay monotone.
        let (e2, b2) = d.set_job_consumers(j.job_id, 2).unwrap();
        assert_eq!(e2, 2);
        assert!(b2 >= 5, "barriers are monotone across epochs");
        // The worker's heartbeat carries the full (idempotent) schedule.
        let whb: WorkerHeartbeatResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::WORKER_HEARTBEAT,
            &WorkerHeartbeatReq {
                worker_id: w.worker_id,
                active_tasks: vec![j.job_id],
                cpu_util_milli: 0,
                spill_manifests: vec![],
                revoke_acks: vec![],
                drain_ready: false,
            },
            timeout(),
        )
        .unwrap();
        let upd = whb
            .width_updates
            .iter()
            .rev()
            .find(|u| u.job_id == j.job_id)
            .expect("width schedule pushed to the worker");
        assert_eq!(upd.width_epochs.len(), 3, "epoch 0 plus two changes");
        assert_eq!(d.metrics().counter("dispatcher/consumer_set_changes").get(), 2);
    }

    #[test]
    fn spill_manifests_commit_and_resubmit_serves_snapshot() {
        use crate::service::spill::{data_key, SegmentMeta};
        let (d, pool, addr) = disp();
        let ds = register_range_dataset(&pool, &addr);
        let w: RegisterWorkerResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::REGISTER_WORKER,
            &RegisterWorkerReq { addr: "127.0.0.1:7007".into() },
            timeout(),
        )
        .unwrap();
        let a: GetOrCreateJobResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::GET_OR_CREATE_JOB,
            &job_req(ds, "", SharingMode::Auto),
            timeout(),
        )
        .unwrap();
        assert!(!a.snapshot, "no snapshot exists yet: live production");

        // The (single) worker reports a complete epoch manifest: the
        // dispatcher merges, journals, publishes, and acks in one step.
        let man = SpillManifest {
            fingerprint: ds,
            job_id: a.job_id,
            epoch: 0,
            total_elements: 4,
            complete: true,
            segments: vec![
                SegmentMeta {
                    key: data_key(a.job_id),
                    offset: 0,
                    len: 40,
                    start_seq: 0,
                    num_elements: 2,
                    crc32: 7,
                },
                SegmentMeta {
                    key: data_key(a.job_id),
                    offset: 40,
                    len: 40,
                    start_seq: 2,
                    num_elements: 2,
                    crc32: 8,
                },
            ],
        };
        let hb: WorkerHeartbeatResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::WORKER_HEARTBEAT,
            &WorkerHeartbeatReq {
                worker_id: w.worker_id,
                active_tasks: vec![a.job_id],
                cpu_util_milli: 0,
                spill_manifests: vec![man.clone()],
                revoke_acks: vec![],
                drain_ready: false,
            },
            timeout(),
        )
        .unwrap();
        assert_eq!(hb.manifest_acks, vec![a.job_id], "commit acks the manifest");
        assert_eq!(d.metrics().counter("dispatcher/snapshots_committed").get(), 1);
        // Re-reporting after the commit is acked without a second merge.
        let hb2: WorkerHeartbeatResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::WORKER_HEARTBEAT,
            &WorkerHeartbeatReq {
                worker_id: w.worker_id,
                active_tasks: vec![a.job_id],
                cpu_util_milli: 0,
                spill_manifests: vec![man],
                revoke_acks: vec![],
                drain_ready: false,
            },
            timeout(),
        )
        .unwrap();
        assert_eq!(hb2.manifest_acks, vec![a.job_id]);
        assert_eq!(d.metrics().counter("dispatcher/snapshots_committed").get(), 1);

        let _: ReleaseJobResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::RELEASE_JOB,
            &ReleaseJobReq { job_id: a.job_id, client_id: a.client_id },
            timeout(),
        )
        .unwrap();

        // Re-submitted identical pipeline (same fingerprint, auto
        // sharing, no live job left): attaches in snapshot-serve mode.
        let b: GetOrCreateJobResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::GET_OR_CREATE_JOB,
            &job_req(ds, "", SharingMode::Auto),
            timeout(),
        )
        .unwrap();
        assert!(b.snapshot, "re-submission is served from the snapshot");
        assert_ne!(b.job_id, a.job_id);
        assert_eq!(d.metrics().counter("dispatcher/snapshot_attaches").get(), 1);
        // The worker's task carries its stripe of the committed manifest.
        let hb3: WorkerHeartbeatResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::WORKER_HEARTBEAT,
            &WorkerHeartbeatReq {
                worker_id: w.worker_id,
                active_tasks: vec![],
                cpu_util_milli: 0,
                spill_manifests: vec![],
                revoke_acks: vec![],
                drain_ready: false,
            },
            timeout(),
        )
        .unwrap();
        let task = hb3
            .new_tasks
            .iter()
            .find(|t| t.job_id == b.job_id)
            .expect("snapshot task delivered");
        let slice = task.snapshot_manifest.as_ref().expect("manifest slice attached");
        assert_eq!(slice.total_elements, 4, "single worker serves the whole epoch");
        assert_eq!(slice.segments.len(), 2);
        // A second client arriving while the snapshot job is live shares
        // it (ordinary auto-sharing attach) and learns it is a snapshot.
        let c: GetOrCreateJobResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::GET_OR_CREATE_JOB,
            &job_req(ds, "", SharingMode::Auto),
            timeout(),
        )
        .unwrap();
        assert!(c.attached && c.snapshot);
        assert_eq!(c.job_id, b.job_id);
    }

    #[test]
    fn snapshot_commit_survives_restart_via_journal() {
        use crate::service::spill::{data_key, SegmentMeta};
        let dir =
            std::env::temp_dir().join(format!("tfdatasvc-disp-snap-{}", std::process::id()));
        let jpath = dir.join("journal");
        let _ = std::fs::remove_file(&jpath);
        let cfg = || DispatcherConfig {
            journal_path: Some(jpath.clone()),
            ..DispatcherConfig::default()
        };
        let pool = Pool::with_defaults();
        let d1 = Dispatcher::start("127.0.0.1:0", cfg()).unwrap();
        let addr = d1.addr();
        let ds = register_range_dataset(&pool, &addr);
        let w: RegisterWorkerResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::REGISTER_WORKER,
            &RegisterWorkerReq { addr: "127.0.0.1:7008".into() },
            timeout(),
        )
        .unwrap();
        let a: GetOrCreateJobResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::GET_OR_CREATE_JOB,
            &job_req(ds, "", SharingMode::Auto),
            timeout(),
        )
        .unwrap();
        let man = SpillManifest {
            fingerprint: ds,
            job_id: a.job_id,
            epoch: 0,
            total_elements: 2,
            complete: true,
            segments: vec![SegmentMeta {
                key: data_key(a.job_id),
                offset: 0,
                len: 40,
                start_seq: 0,
                num_elements: 2,
                crc32: 7,
            }],
        };
        let hb: WorkerHeartbeatResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::WORKER_HEARTBEAT,
            &WorkerHeartbeatReq {
                worker_id: w.worker_id,
                active_tasks: vec![a.job_id],
                cpu_util_milli: 0,
                spill_manifests: vec![man],
                revoke_acks: vec![],
                drain_ready: false,
            },
            timeout(),
        )
        .unwrap();
        assert_eq!(hb.manifest_acks, vec![a.job_id]);
        let _: ReleaseJobResp = call_typed(
            &pool,
            &addr,
            dispatcher_methods::RELEASE_JOB,
            &ReleaseJobReq { job_id: a.job_id, client_id: a.client_id },
            timeout(),
        )
        .unwrap();
        drop(d1);

        // Restart from the journal: the committed snapshot must still be
        // discoverable by a re-submitted identical pipeline.
        let d2 = Dispatcher::start("127.0.0.1:0", cfg()).unwrap();
        let addr2 = d2.addr();
        let b: GetOrCreateJobResp = call_typed(
            &pool,
            &addr2,
            dispatcher_methods::GET_OR_CREATE_JOB,
            &job_req(ds, "", SharingMode::Auto),
            timeout(),
        )
        .unwrap();
        assert!(b.snapshot, "snapshot commit survives the restart");
    }
}
