//! CRC-32 (IEEE 802.3, reflected, polynomial 0xEDB88320).
//!
//! In-tree replacement for the usual `crc32fast` dependency (the build is
//! fully offline). The [`Hasher`] API matches it: `new` / `update` /
//! `finalize`. Used by the dispatcher journal and the storage record
//! framing to detect torn or corrupted writes.

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = make_table();

/// Incremental CRC-32 state.
#[derive(Debug, Clone)]
pub struct Hasher {
    state: u32,
}

impl Default for Hasher {
    fn default() -> Self {
        Hasher::new()
    }
}

impl Hasher {
    pub fn new() -> Hasher {
        Hasher { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    pub fn finalize(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot convenience.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(bytes);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // zlib.crc32 reference values.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        let all: Vec<u8> = (0u8..=255).collect();
        assert_eq!(crc32(&all), 0x2905_8C73);
    }

    #[test]
    fn incremental_matches_oneshot() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let mut h = Hasher::new();
        h.update(&data[..10]);
        h.update(&data[10..]);
        assert_eq!(h.finalize(), crc32(data));
    }

    #[test]
    fn detects_single_bit_flip() {
        let mut data = vec![7u8; 64];
        let a = crc32(&data);
        data[33] ^= 1;
        assert_ne!(a, crc32(&data));
    }
}
