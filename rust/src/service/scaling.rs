//! Closed-loop autoscaling: the control loop that closes §3.1's
//! Autopilot over live service signals.
//!
//! The paper scales worker pools from "user hints and CPU utilization";
//! Cachew-style policies additionally watch client batch times. The
//! [`crate::orchestrator::Autoscaler`] holds that *policy*; this module
//! supplies the *plant and sensor loop* around it:
//!
//! 1. **Sense** — worker heartbeats carry `cpu_util_milli`, client
//!    heartbeats carry `stall_fraction_milli` (the fraction of fetches
//!    that found no element buffered). The dispatcher folds both into a
//!    [`crate::service::dispatcher::ScalingSnapshot`].
//! 2. **Decide** — at ~1 Hz the controller turns the snapshot into
//!    [`Signals`] and asks the autoscaler for a [`Decision`]; cooldown
//!    and min/max bounds live in the policy, not here.
//! 3. **Actuate** — `ScaleTo(n)` routes through
//!    [`Cell::request_scale_to`]: scale-up adds workers immediately,
//!    scale-down *begins* two-phase graceful drains of the least-loaded
//!    workers. The loop also drives [`Cell::tick`] +
//!    [`Cell::reap_drained`] every interval, so planned drains make
//!    progress and drained workers are removed — mid-job, without a
//!    client-visible stall.
//!
//! Telemetry (on [`ScalingController::metrics`]): counters
//! `scaling/evaluations`, `scaling/scale_ups`, `scaling/scale_downs`;
//! gauge `scaling/target_workers`; time series `scaling/workers`,
//! `scaling/util`, `scaling/starvation` (the closed-loop bench plots the
//! worker-count trajectory against offered load from these).

use crate::metrics::Registry;
use crate::orchestrator::autoscaler::{Decision, Signals};
use crate::orchestrator::{Autoscaler, AutoscalerConfig, Cell};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Control-loop knobs (policy knobs live in [`AutoscalerConfig`]).
#[derive(Debug, Clone)]
pub struct ScalingConfig {
    /// Sense/decide/actuate period (~1 Hz by default).
    pub interval: Duration,
    pub autoscaler: AutoscalerConfig,
}

impl Default for ScalingConfig {
    fn default() -> Self {
        ScalingConfig { interval: Duration::from_secs(1), autoscaler: AutoscalerConfig::default() }
    }
}

/// Handle to a running control loop; dropping stops (and joins) it.
pub struct ScalingController {
    stop: Arc<AtomicBool>,
    /// Controller telemetry (see module docs for the metric names).
    pub metrics: Registry,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl ScalingController {
    /// Start the closed loop against `cell`.
    pub fn start(cell: Arc<Cell>, cfg: ScalingConfig) -> ScalingController {
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Registry::new();
        let (s2, m2) = (stop.clone(), metrics.clone());
        let thread = std::thread::Builder::new()
            .name("scaling-controller".into())
            .spawn(move || {
                let mut scaler = Autoscaler::new(cfg.autoscaler.clone());
                while !s2.load(Ordering::SeqCst) {
                    // Interruptible sleep: the interval is long (~1 s), so
                    // wake in small steps to keep stop()/Drop responsive.
                    let mut waited = Duration::ZERO;
                    while waited < cfg.interval && !s2.load(Ordering::SeqCst) {
                        let step = (cfg.interval - waited).min(Duration::from_millis(25));
                        std::thread::sleep(step);
                        waited += step;
                    }
                    if s2.load(Ordering::SeqCst) {
                        break;
                    }
                    // Drive drains forward and reap the finished ones
                    // before sensing, so capacity reflects this instant.
                    cell.tick();
                    cell.reap_drained();
                    let snap = cell.dispatcher().scaling_snapshot();
                    let signals = Signals {
                        current_workers: snap.live_workers,
                        mean_worker_util: snap.mean_worker_util,
                        client_starvation: snap.client_starvation,
                    };
                    m2.counter("scaling/evaluations").inc();
                    m2.series("scaling/workers").record(snap.live_workers as f64);
                    m2.series("scaling/util").record(snap.mean_worker_util);
                    m2.series("scaling/starvation").record(snap.client_starvation);
                    match scaler.evaluate(signals) {
                        Decision::Hold => {}
                        Decision::ScaleTo(n) => {
                            if n > snap.live_workers {
                                m2.counter("scaling/scale_ups").inc();
                            } else {
                                m2.counter("scaling/scale_downs").inc();
                            }
                            m2.gauge("scaling/target_workers").set(n as i64);
                            // Non-blocking: adds run now, drains begin now
                            // and complete via the tick/reap above.
                            let _ = cell.request_scale_to(n);
                        }
                    }
                }
            })
            .ok();
        ScalingController { stop, metrics, thread }
    }

    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }
}

impl Drop for ScalingController {
    fn drop(&mut self) {
        self.stop();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::udf::UdfRegistry;
    use crate::service::dispatcher::DispatcherConfig;
    use crate::storage::ObjectStore;
    use std::time::Instant;

    fn mk_cell() -> Arc<Cell> {
        let store = ObjectStore::in_memory();
        Arc::new(
            Cell::new(store, UdfRegistry::with_builtins(), DispatcherConfig::default()).unwrap(),
        )
    }

    #[test]
    fn controller_enforces_min_workers() {
        let cell = mk_cell();
        let ctl = ScalingController::start(
            cell.clone(),
            ScalingConfig {
                interval: Duration::from_millis(50),
                autoscaler: AutoscalerConfig {
                    min_workers: 2,
                    cooldown: Duration::ZERO,
                    ..Default::default()
                },
            },
        );
        let deadline = Instant::now() + Duration::from_secs(5);
        while cell.worker_count() < 2 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(20));
        }
        ctl.stop();
        assert!(cell.worker_count() >= 2, "scaled up to the floor");
        assert!(ctl.metrics.counter("scaling/evaluations").get() >= 1);
        assert!(ctl.metrics.counter("scaling/scale_ups").get() >= 1);
    }

    #[test]
    fn controller_drains_idle_workers_down() {
        let cell = mk_cell();
        cell.scale_to(4).unwrap();
        let ctl = ScalingController::start(
            cell.clone(),
            ScalingConfig {
                interval: Duration::from_millis(50),
                autoscaler: AutoscalerConfig {
                    min_workers: 1,
                    cooldown: Duration::ZERO,
                    ..Default::default()
                },
            },
        );
        // Idle workers report ~0 CPU: the loop shrinks 4 -> 3 -> 2 -> 1
        // through the graceful-drain path.
        let deadline = Instant::now() + Duration::from_secs(10);
        while cell.worker_count() > 1 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(20));
        }
        ctl.stop();
        assert_eq!(cell.worker_count(), 1, "drained down to the floor");
        assert!(ctl.metrics.counter("scaling/scale_downs").get() >= 1);
        let drained = cell.dispatcher().metrics().counter("dispatcher/workers_drained").get();
        assert!(drained >= 3, "scale-down went through graceful drains (got {drained})");
    }
}
