//! Growable write buffer, bounds-checked read cursor, and a reusable
//! encode-buffer pool for batched response frames.

use super::{WireError, WireResult};
use std::sync::Mutex;

/// Append-only little-endian write buffer.
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Writer { buf: Vec::new() }
    }

    pub fn with_capacity(cap: usize) -> Self {
        Writer { buf: Vec::with_capacity(cap) }
    }

    /// Wrap a recycled buffer (cleared, capacity kept) — the
    /// [`BufPool`] fast path, so batched encodes reuse allocations.
    pub fn from_vec(mut buf: Vec<u8>) -> Self {
        buf.clear();
        Writer { buf }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed byte string.
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    /// Raw append, no length prefix (for pre-framed payloads).
    pub fn put_raw(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    /// Overwrite 4 bytes at `at` (used to back-patch frame lengths).
    pub fn patch_u32(&mut self, at: usize, v: u32) {
        self.buf[at..at + 4].copy_from_slice(&v.to_le_bytes());
    }
}

/// Pool of reusable encode buffers for the batched data plane.
///
/// A multi-megabyte `GetElements` frame encoded into a fresh `Vec` pays
/// a chain of doubling reallocations per response; taking a recycled
/// buffer (or a fresh one pre-sized to the pool's high-water capacity)
/// makes frame assembly a single allocation at steady state. Buffers
/// that leave with the response are simply not returned; the pool
/// refills from paths that finish with the scratch buffer (e.g. the
/// compressed path, which copies the compressed frame out).
#[derive(Debug, Default)]
pub struct BufPool {
    inner: Mutex<BufPoolInner>,
    max_pooled: usize,
}

#[derive(Debug, Default)]
struct BufPoolInner {
    bufs: Vec<Vec<u8>>,
    /// Largest capacity ever returned; fresh buffers pre-size to this.
    cap_hint: usize,
}

impl BufPool {
    /// A pool retaining at most `max_pooled` idle buffers.
    pub fn new(max_pooled: usize) -> BufPool {
        BufPool { inner: Mutex::new(BufPoolInner::default()), max_pooled: max_pooled.max(1) }
    }

    /// Take a cleared buffer: recycled if available, else freshly
    /// allocated at the observed high-water capacity.
    pub fn take(&self) -> Vec<u8> {
        let mut g = self.inner.lock().unwrap();
        match g.bufs.pop() {
            Some(mut b) => {
                b.clear();
                b
            }
            None => Vec::with_capacity(g.cap_hint),
        }
    }

    /// Return a buffer for reuse. Keeps at most `max_pooled`.
    pub fn put(&self, buf: Vec<u8>) {
        let mut g = self.inner.lock().unwrap();
        g.cap_hint = g.cap_hint.max(buf.capacity());
        if g.bufs.len() < self.max_pooled {
            g.bufs.push(buf);
        }
    }

    /// Record the capacity of a buffer that is about to leave with a
    /// response (zero-copy tail) instead of coming back via `put`: future
    /// fresh takes still pre-size to the high-water mark, so frame
    /// assembly stays a single allocation even when no buffer is ever
    /// returned.
    pub fn record_capacity(&self, cap: usize) {
        let mut g = self.inner.lock().unwrap();
        g.cap_hint = g.cap_hint.max(cap);
    }

    /// Idle buffers currently held.
    pub fn idle(&self) -> usize {
        self.inner.lock().unwrap().bufs.len()
    }
}

/// Bounds-checked read cursor over a byte slice.
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn position(&self) -> usize {
        self.pos
    }

    fn take(&mut self, n: usize) -> WireResult<&'a [u8]> {
        if self.remaining() < n {
            return Err(WireError::Eof { wanted: n, remaining: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Guard against hostile counts: a count-prefixed sequence of `n`
    /// elements each at least `min_elem_size` bytes cannot exceed the
    /// remaining buffer.
    pub fn check_count(&self, n: usize, min_elem_size: usize) -> WireResult<()> {
        if n.saturating_mul(min_elem_size) > self.remaining() {
            return Err(WireError::TooLong { len: n, limit: self.remaining() });
        }
        Ok(())
    }

    pub fn get_u8(&mut self) -> WireResult<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u16(&mut self) -> WireResult<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn get_u32(&mut self) -> WireResult<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> WireResult<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_i32(&mut self) -> WireResult<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_i64(&mut self) -> WireResult<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f32(&mut self) -> WireResult<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> WireResult<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_bytes(&mut self) -> WireResult<Vec<u8>> {
        let n = self.get_u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    /// Borrowed variant of [`Reader::get_bytes`] (zero-copy hot path).
    pub fn get_bytes_ref(&mut self) -> WireResult<&'a [u8]> {
        let n = self.get_u32()? as usize;
        self.take(n)
    }

    /// Raw read of exactly `n` bytes.
    pub fn get_raw(&mut self, n: usize) -> WireResult<&'a [u8]> {
        self.take(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_reader_roundtrip() {
        let mut w = Writer::new();
        w.put_u8(1);
        w.put_u16(2);
        w.put_u32(3);
        w.put_u64(4);
        w.put_i32(-5);
        w.put_i64(-6);
        w.put_f32(7.5);
        w.put_f64(-8.25);
        w.put_bytes(b"abc");
        let b = w.into_bytes();
        let mut r = Reader::new(&b);
        assert_eq!(r.get_u8().unwrap(), 1);
        assert_eq!(r.get_u16().unwrap(), 2);
        assert_eq!(r.get_u32().unwrap(), 3);
        assert_eq!(r.get_u64().unwrap(), 4);
        assert_eq!(r.get_i32().unwrap(), -5);
        assert_eq!(r.get_i64().unwrap(), -6);
        assert_eq!(r.get_f32().unwrap(), 7.5);
        assert_eq!(r.get_f64().unwrap(), -8.25);
        assert_eq!(r.get_bytes().unwrap(), b"abc");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn eof_detected() {
        let mut r = Reader::new(&[1, 2]);
        assert!(r.get_u32().is_err());
        // failed read must not consume
        assert_eq!(r.remaining(), 2);
        assert_eq!(r.get_u16().unwrap(), 0x0201);
    }

    #[test]
    fn patch_u32() {
        let mut w = Writer::new();
        w.put_u32(0); // placeholder
        w.put_raw(b"xyz");
        let at = 0;
        w.patch_u32(at, 3);
        let b = w.into_bytes();
        let mut r = Reader::new(&b);
        assert_eq!(r.get_u32().unwrap(), 3);
        assert_eq!(r.get_raw(3).unwrap(), b"xyz");
    }

    #[test]
    fn buf_pool_recycles_and_caps() {
        let pool = BufPool::new(2);
        let mut a = pool.take();
        assert_eq!(a.capacity(), 0, "no hint yet");
        a.extend_from_slice(&[1, 2, 3]);
        a.reserve(1024);
        let cap = a.capacity();
        pool.put(a);
        assert_eq!(pool.idle(), 1);
        // Recycled buffer comes back cleared with its capacity intact.
        let b = pool.take();
        assert!(b.is_empty());
        assert_eq!(b.capacity(), cap);
        assert_eq!(pool.idle(), 0);
        // Fresh takes pre-size to the high-water capacity.
        let c = pool.take();
        assert!(c.capacity() >= cap);
        // The pool never holds more than max_pooled buffers.
        pool.put(b);
        pool.put(c);
        pool.put(Vec::new());
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    fn writer_from_vec_clears_and_reuses() {
        let mut v = Vec::with_capacity(64);
        v.extend_from_slice(b"stale");
        let cap = v.capacity();
        let mut w = Writer::from_vec(v);
        assert!(w.is_empty());
        w.put_bytes(b"fresh");
        let out = w.into_bytes();
        assert_eq!(out.capacity(), cap, "allocation reused");
        let mut r = Reader::new(&out);
        assert_eq!(r.get_bytes().unwrap(), b"fresh");
    }

    #[test]
    fn zero_copy_bytes_ref() {
        let mut w = Writer::new();
        w.put_bytes(b"hello");
        let b = w.into_bytes();
        let mut r = Reader::new(&b);
        assert_eq!(r.get_bytes_ref().unwrap(), b"hello");
    }
}
