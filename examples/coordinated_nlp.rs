//! Coordinated reads (§3.6 / Fig. 11): distributed NLP training where
//! every training round feeds all clients batches from the same
//! sequence-length bucket.
//!
//! Measures, live on the real service: (a) per-round bucket agreement
//! across clients, (b) padding waste with vs without coordination, and
//! (c) modeled step-time speedup from the measured padded sizes.
//!
//! Run: `cargo run --release --example coordinated_nlp`

use std::sync::Arc;
use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::orchestrator::Cell;
use tfdatasvc::service::dispatcher::DispatcherConfig;
use tfdatasvc::service::proto::{ProcessingMode, ShardingPolicy};
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::storage::dataset::{generate_text, TextGenConfig};
use tfdatasvc::storage::ObjectStore;
use tfdatasvc::train::padding_fraction;
use tfdatasvc::util::cli::Args;

const BATCH: u32 = 8;

fn consume(
    mut it: tfdatasvc::service::client::DistributedIter,
    rounds: usize,
) -> Vec<(Option<u32>, usize, f64)> {
    let mut out = Vec::new();
    for _ in 0..rounds {
        match it.next() {
            Ok(Some(e)) => {
                let padded = e.tensors[0].shape[1];
                out.push((e.bucket, padded, padding_fraction(&e)));
            }
            _ => break,
        }
    }
    out
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    let rounds = args.usize_or("rounds", 16);
    let num_consumers = 2u32;

    let store = ObjectStore::in_memory();
    let spec = generate_text(
        &store,
        "datasets/nlp",
        &TextGenConfig {
            num_shards: 4,
            samples_per_shard: 2048,
            len_mu: 4.0,
            len_sigma: 1.0,
            max_len: 512,
            ..Default::default()
        },
    );
    let cell = Arc::new(Cell::new(store, UdfRegistry::with_builtins(), DispatcherConfig::default())?);
    cell.scale_to(2)?;

    // ---- Uncoordinated baseline: plain padded batches, two clients ----
    let base_graph = PipelineBuilder::source_text(spec.clone())
        .padded_batch(BATCH)
        .take(rounds as u64 * 2)
        .build();
    let c = ServiceClient::new(&cell.dispatcher_addr());
    let base_iter = c.distribute(
        &base_graph,
        ServiceClientConfig { sharding: ShardingPolicy::Off, ..Default::default() },
    )?;
    let baseline = consume(base_iter, rounds);
    let base_pad: f64 = baseline.iter().map(|r| r.2).sum::<f64>() / baseline.len() as f64;

    // ---- Coordinated: Fig. 7 pipeline + coordinated job ----
    let coord_graph = PipelineBuilder::source_text(spec)
        .bucket_by_sequence_length(vec![64, 128, 192, 256, 320, 384, 448], BATCH)
        .group_by_window(num_consumers)
        .flat_map()
        .take(rounds as u64 * num_consumers as u64 * 4)
        .build();
    let mk = |ci: u32| ServiceClientConfig {
        sharding: ShardingPolicy::Off,
        mode: ProcessingMode::Coordinated,
        job_name: "coord-demo".into(),
        num_consumers,
        consumer_index: ci,
        ..Default::default()
    };
    let c0 = ServiceClient::new(&cell.dispatcher_addr());
    let c1 = ServiceClient::new(&cell.dispatcher_addr());
    let it0 = c0.distribute(&coord_graph, mk(0))?;
    let it1 = c1.distribute(&coord_graph, mk(1))?;
    let h = std::thread::spawn(move || consume(it1, rounds));
    let r0 = consume(it0, rounds);
    let r1 = h.join().unwrap();

    // Per-round bucket agreement (§3.6's core property).
    let n = r0.len().min(r1.len());
    assert!(n > 0, "coordinated rounds produced no data");
    let mut agree = 0;
    for i in 0..n {
        if r0[i].0 == r1[i].0 {
            agree += 1;
        }
    }
    println!("rounds consumed: {n}; same-bucket agreement: {agree}/{n}");
    assert_eq!(agree, n, "every round must serve one bucket to all clients");

    let coord_pad: f64 =
        r0.iter().chain(&r1).map(|r| r.2).sum::<f64>() / (r0.len() + r1.len()) as f64;
    println!("padding waste:  uncoordinated {:.1}%  coordinated {:.1}%", base_pad * 100.0, coord_pad * 100.0);
    assert!(coord_pad < base_pad, "coordination must reduce padding");

    // Modeled step-time gain from measured padded lengths: step ∝ padded
    // tokens, wall = max across clients per round.
    let mut un_time = 0.0;
    for w in baseline.chunks(2) {
        un_time += w.iter().map(|r| r.1 as f64).fold(0.0, f64::max);
    }
    let mut co_time = 0.0;
    for i in 0..n {
        co_time += (r0[i].1 as f64).max(r1[i].1 as f64);
    }
    let speedup = (un_time / baseline.len() as f64) / (co_time / n as f64);
    println!("modeled step-time speedup from coordination: {speedup:.2}x (paper: 1.5-3.5x)");
    println!("coordinated_nlp OK");
    Ok(())
}
