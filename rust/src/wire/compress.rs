//! Wire-frame compression: a small self-contained LZ77 codec.
//!
//! In-tree replacement for the `flate2` dependency (the build is fully
//! offline). The worker compresses whole `GetElements` response frames —
//! amortizing the codec's token overhead across every element in the
//! batch — and single `GetElement` payloads with the same codec. The
//! format is internal to the service (both sides of the wire are this
//! crate), so there is no need for deflate compatibility:
//!
//! ```text
//! | raw_len: u32 LE | token* |
//! token := 0x00 | run_len: u16 LE | run_len literal bytes
//!        | 0x01 | match_len: u16 LE | distance: u16 LE
//! ```
//!
//! Matches are at least [`MIN_MATCH`] bytes and may overlap their own
//! output (distance < length encodes a repeating pattern), which is what
//! makes constant-filled tensors collapse to a few tokens.

use super::{WireError, WireResult};
use std::collections::HashMap;
use std::sync::Mutex;

/// Shortest match worth a 5-byte token.
const MIN_MATCH: usize = 6;
/// Token length fields are u16.
const MAX_CHUNK: usize = u16::MAX as usize;
/// Match distances are u16 (64 KiB window).
const MAX_DISTANCE: usize = u16::MAX as usize;

const TAG_LITERAL: u8 = 0;
const TAG_MATCH: u8 = 1;

fn hash3(d: &[u8], mask: usize) -> usize {
    let v = (d[0] as u32) | ((d[1] as u32) << 8) | ((d[2] as u32) << 16);
    (v.wrapping_mul(2654435761) >> 16) as usize & mask
}

fn emit_literals(out: &mut Vec<u8>, data: &[u8]) {
    for chunk in data.chunks(MAX_CHUNK) {
        out.push(TAG_LITERAL);
        out.extend_from_slice(&(chunk.len() as u16).to_le_bytes());
        out.extend_from_slice(chunk);
    }
}

/// Compress `data`. Output is never much larger than the input
/// (3 bytes of framing per 64 KiB literal run, plus the 4-byte header).
pub fn compress(data: &[u8]) -> Vec<u8> {
    let n = data.len();
    let mut out = Vec::with_capacity(n / 2 + 16);
    out.extend_from_slice(&(n as u32).to_le_bytes());
    // Last position each 3-byte hash was seen at. Sized to the input so
    // small payloads (the single-element GetElement path) don't pay a
    // fixed 64 Ki-entry table fill per call; extra collisions on small
    // inputs only cost missed matches, never correctness.
    let table_len = n.next_power_of_two().clamp(1 << 8, 1 << 16);
    let mask = table_len - 1;
    let mut table = vec![usize::MAX; table_len];
    let mut i = 0usize;
    let mut lit_start = 0usize;
    while i + 3 <= n {
        let h = hash3(&data[i..], mask);
        let cand = table[h];
        table[h] = i;
        if cand != usize::MAX
            && i - cand <= MAX_DISTANCE
            && data[cand..cand + 3] == data[i..i + 3]
        {
            let mut len = 3;
            while i + len < n && len < MAX_CHUNK && data[cand + len] == data[i + len] {
                len += 1;
            }
            if len >= MIN_MATCH {
                emit_literals(&mut out, &data[lit_start..i]);
                out.push(TAG_MATCH);
                out.extend_from_slice(&(len as u16).to_le_bytes());
                out.extend_from_slice(&((i - cand) as u16).to_le_bytes());
                i += len;
                lit_start = i;
                continue;
            }
        }
        i += 1;
    }
    emit_literals(&mut out, &data[lit_start..n]);
    out
}

/// Decompress a [`compress`]-produced buffer, validating framing.
pub fn decompress(bytes: &[u8]) -> WireResult<Vec<u8>> {
    if bytes.len() < 4 {
        return Err(WireError::Eof { wanted: 4, remaining: bytes.len() });
    }
    let raw_len = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
    let mut out = Vec::with_capacity(raw_len.min(1 << 24));
    let mut pos = 4usize;
    while pos < bytes.len() {
        let tag = bytes[pos];
        pos += 1;
        match tag {
            TAG_LITERAL => {
                if bytes.len() - pos < 2 {
                    return Err(WireError::Eof { wanted: 2, remaining: bytes.len() - pos });
                }
                let len = u16::from_le_bytes(bytes[pos..pos + 2].try_into().unwrap()) as usize;
                pos += 2;
                if bytes.len() - pos < len {
                    return Err(WireError::Eof { wanted: len, remaining: bytes.len() - pos });
                }
                out.extend_from_slice(&bytes[pos..pos + len]);
                pos += len;
            }
            TAG_MATCH => {
                if bytes.len() - pos < 4 {
                    return Err(WireError::Eof { wanted: 4, remaining: bytes.len() - pos });
                }
                let len = u16::from_le_bytes(bytes[pos..pos + 2].try_into().unwrap()) as usize;
                let dist = u16::from_le_bytes(bytes[pos + 2..pos + 4].try_into().unwrap()) as usize;
                pos += 4;
                if dist == 0 || dist > out.len() {
                    return Err(WireError::Other(format!(
                        "lz match distance {dist} exceeds output length {}",
                        out.len()
                    )));
                }
                // Byte-wise copy: overlapping matches (dist < len) are the
                // run-length-encoding case and must see their own output.
                for _ in 0..len {
                    let b = out[out.len() - dist];
                    out.push(b);
                }
            }
            other => {
                return Err(WireError::BadTag { tag: other, ty: "lz token" });
            }
        }
        if out.len() > raw_len {
            return Err(WireError::TooLong { len: out.len(), limit: raw_len });
        }
    }
    if out.len() != raw_len {
        return Err(WireError::Other(format!(
            "lz frame decoded {} bytes, header said {raw_len}",
            out.len()
        )));
    }
    Ok(out)
}

/// Payloads below this never win: literal-token framing plus the 4-byte
/// raw-length header eats any plausible saving, so the chooser sends
/// them raw without spending a trial compression.
pub const CODEC_MIN_LEN: usize = 64;

/// What [`AdaptiveCodec::plan`] tells the caller to do with a payload.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum CodecAction {
    /// Probe: compress this payload and report the observed ratio via
    /// [`AdaptiveCodec::record_trial`] (the caller keeps the compressed
    /// bytes if they won — a trial is never wasted work).
    Trial,
    /// Sticky decision for this shape class: compress.
    Compress,
    /// Sticky decision for this shape class: send raw.
    Skip,
}

/// Per-shape-class chooser state. `decision` is the sticky verdict
/// (`None` while the probe window is still open).
#[derive(Default)]
struct ClassState {
    probes_left: u32,
    /// Trials in the current probe window whose compressed output beat
    /// the worthwhile threshold.
    wins: u32,
    /// `Some(true)` = compress, `Some(false)` = skip.
    decision: Option<bool>,
    /// Payloads served since the class settled (re-probe clock).
    uses: u64,
}

/// Observed-ratio compression chooser (ROADMAP raw-speed item: "skip/LZ
/// chosen by observed ratio, not config").
///
/// The wire codec used to be config-frozen: a client asking for
/// `Deflate` bought a trial compression of *every* response frame, and
/// incompressible tensors (random augmentation output, already-encoded
/// images) paid the full LZ pass just to discover the raw bytes were
/// smaller. The chooser amortizes that discovery per **element-shape
/// class** (payload size bucketed by power of two — batches of one
/// pipeline shape land in one bucket, a mid-stream shape change lands
/// in a fresh one):
///
/// ```text
///            plan() == Trial                 plan() == Compress/Skip
///   [probing: probes_left > 0] --settle--> [settled: sticky decision]
///            ^     record_trial majority        |
///            |                                  | every reprobe_every
///            +-------- fresh class              v uses: one Trial
///                                        [re-probe sample] --flip?-->
///                                          switched (counted)
/// ```
///
/// * **Probe phase** — the first `probe_samples` payloads of a class are
///   trial-compressed (the caller still ships the winner, so probing
///   costs nothing extra over the old behavior). A majority of
///   worthwhile ratios settles the class on LZ, otherwise on Skip.
/// * **Sticky phase** — settled classes answer `plan` without touching
///   the codec: a Skip class serves raw bytes at memcpy speed.
/// * **Re-probe** — every `reprobe_every` settled uses, one payload is
///   trial-compressed again so content drift (same shape, new
///   compressibility) flips the decision; flips are reported so the
///   worker can meter `worker/codec_switches`.
///
/// Decisions only pick which bytes ride the wire; the per-response
/// `compressed` flag keeps every mix of decisions byte-identical after
/// decode.
pub struct AdaptiveCodec {
    classes: Mutex<HashMap<u32, ClassState>>,
    probe_samples: u32,
    reprobe_every: u64,
}

impl Default for AdaptiveCodec {
    fn default() -> Self {
        AdaptiveCodec::new()
    }
}

impl AdaptiveCodec {
    pub fn new() -> AdaptiveCodec {
        AdaptiveCodec::with_config(4, 512)
    }

    /// `probe_samples`: trials before a fresh class settles.
    /// `reprobe_every`: settled uses between single-sample re-probes.
    pub fn with_config(probe_samples: u32, reprobe_every: u64) -> AdaptiveCodec {
        AdaptiveCodec {
            classes: Mutex::new(HashMap::new()),
            probe_samples: probe_samples.max(1),
            reprobe_every: reprobe_every.max(1),
        }
    }

    /// Shape class of a payload: size bucketed by power of two. Batches
    /// of one element shape produce near-identical frame sizes, so they
    /// share a bucket; a mid-stream shape change moves to a fresh bucket
    /// and re-enters the probe phase.
    fn class_of(len: usize) -> u32 {
        usize::BITS - (len | 1).leading_zeros()
    }

    /// A trial is worthwhile when compression saves at least 10% — below
    /// that the decode cost on the client outweighs the wire saving.
    fn worthwhile(raw_len: usize, compressed_len: usize) -> bool {
        compressed_len.saturating_mul(10) <= raw_len.saturating_mul(9)
    }

    /// Decide what to do with a payload of `len` bytes.
    pub fn plan(&self, len: usize) -> CodecAction {
        if len < CODEC_MIN_LEN {
            return CodecAction::Skip;
        }
        let mut classes = self.classes.lock().unwrap();
        let st = classes.entry(Self::class_of(len)).or_insert_with(|| ClassState {
            probes_left: self.probe_samples,
            ..Default::default()
        });
        match st.decision {
            None => CodecAction::Trial,
            Some(d) => {
                st.uses += 1;
                if st.uses >= self.reprobe_every {
                    st.uses = 0;
                    CodecAction::Trial
                } else if d {
                    CodecAction::Compress
                } else {
                    CodecAction::Skip
                }
            }
        }
    }

    /// Report a trial compression's outcome. Returns `true` when the
    /// class's sticky decision *flipped* (re-probe detected content
    /// drift) — the caller meters switches; the initial settle of a
    /// fresh class is not a switch.
    pub fn record_trial(&self, raw_len: usize, compressed_len: usize) -> bool {
        let worthwhile = Self::worthwhile(raw_len, compressed_len);
        let mut classes = self.classes.lock().unwrap();
        let st = classes.entry(Self::class_of(raw_len)).or_insert_with(|| ClassState {
            probes_left: self.probe_samples,
            ..Default::default()
        });
        match st.decision {
            None => {
                if worthwhile {
                    st.wins += 1;
                }
                st.probes_left = st.probes_left.saturating_sub(1);
                if st.probes_left == 0 {
                    st.decision = Some(st.wins * 2 >= self.probe_samples);
                    st.wins = 0;
                    st.uses = 0;
                }
                false
            }
            Some(prev) => {
                st.wins = 0;
                st.uses = 0;
                if worthwhile != prev {
                    st.decision = Some(worthwhile);
                    return true;
                }
                false
            }
        }
    }

    /// Settled decision for a payload length (`Some(true)` = compress),
    /// `None` while its class is still probing. Test/bench hook.
    pub fn decision_for_len(&self, len: usize) -> Option<bool> {
        self.classes.lock().unwrap().get(&Self::class_of(len)).and_then(|st| st.decision)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt(data: &[u8]) {
        let z = compress(data);
        assert_eq!(decompress(&z).unwrap(), data, "len {}", data.len());
    }

    #[test]
    fn roundtrips() {
        rt(b"");
        rt(b"a");
        rt(b"hello");
        rt(b"abcabcabcabcabcabcabcabcabc");
        rt(&vec![7u8; 10_000]);
        let mixed: Vec<u8> = (0..5000u32)
            .map(|i| (i.wrapping_mul(2654435761) >> 13) as u8)
            .collect();
        rt(&mixed);
        // Structured data like tensor frames: repeating 128-byte rows.
        let row: Vec<u8> = (0..128u8).collect();
        let frame: Vec<u8> = row.iter().cycle().take(64 * 128).copied().collect();
        rt(&frame);
    }

    #[test]
    fn constant_data_compresses_hard() {
        let data = vec![42u8; 100_000];
        let z = compress(&data);
        assert!(z.len() < data.len() / 50, "{} vs {}", z.len(), data.len());
    }

    #[test]
    fn incompressible_data_bounded_expansion() {
        let data: Vec<u8> = (0..70_000u32)
            .map(|i| {
                let x = i.wrapping_mul(0x9E37_79B9).rotate_left(11).wrapping_add(i);
                (x ^ (x >> 7)) as u8
            })
            .collect();
        let z = compress(&data);
        assert!(z.len() < data.len() + data.len() / 100 + 64);
        assert_eq!(decompress(&z).unwrap(), data);
    }

    /// Pseudo-random bytes the LZ pass cannot shrink.
    fn incompressible(n: usize, seed: u32) -> Vec<u8> {
        (0..n as u32)
            .map(|i| {
                let x = i
                    .wrapping_mul(0x9E37_79B9)
                    .rotate_left(11)
                    .wrapping_add(i)
                    .wrapping_add(seed.wrapping_mul(0x85EB_CA6B));
                (x ^ (x >> 7)) as u8
            })
            .collect()
    }

    /// Repetitive text the LZ pass shrinks hard.
    fn compressible(n: usize) -> Vec<u8> {
        b"the quick brown fox jumps over the lazy dog; "
            .iter()
            .cycle()
            .take(n)
            .copied()
            .collect()
    }

    /// Drive one payload through the chooser exactly like the worker
    /// does, returning the bytes that would ride the wire.
    fn drive(codec: &AdaptiveCodec, data: &[u8]) -> (Vec<u8>, bool, bool) {
        match codec.plan(data.len()) {
            CodecAction::Trial => {
                let z = compress(data);
                let switched = codec.record_trial(data.len(), z.len());
                if z.len() < data.len() {
                    (z, true, switched)
                } else {
                    (data.to_vec(), false, switched)
                }
            }
            CodecAction::Compress => {
                let z = compress(data);
                if z.len() < data.len() {
                    (z, true, false)
                } else {
                    (data.to_vec(), false, false)
                }
            }
            CodecAction::Skip => (data.to_vec(), false, false),
        }
    }

    #[test]
    fn incompressible_settles_on_skip_within_probe_budget() {
        let codec = AdaptiveCodec::with_config(4, 512);
        let data = incompressible(4096, 1);
        for i in 0..4 {
            assert_eq!(codec.plan(data.len()), CodecAction::Trial, "probe {i}");
            let z = compress(&data);
            assert!(!codec.record_trial(data.len(), z.len()), "initial settle is not a switch");
        }
        assert_eq!(codec.decision_for_len(data.len()), Some(false));
        for _ in 0..16 {
            assert_eq!(codec.plan(data.len()), CodecAction::Skip);
        }
    }

    #[test]
    fn compressible_settles_on_lz() {
        let codec = AdaptiveCodec::with_config(4, 512);
        let data = compressible(4096);
        for _ in 0..4 {
            assert_eq!(codec.plan(data.len()), CodecAction::Trial);
            let z = compress(&data);
            codec.record_trial(data.len(), z.len());
        }
        assert_eq!(codec.decision_for_len(data.len()), Some(true));
        for _ in 0..16 {
            assert_eq!(codec.plan(data.len()), CodecAction::Compress);
        }
    }

    #[test]
    fn shape_change_triggers_fresh_probe() {
        let codec = AdaptiveCodec::with_config(2, 512);
        // Settle the ~4 KiB class on Skip.
        let small = incompressible(4096, 2);
        for _ in 0..2 {
            codec.plan(small.len());
            codec.record_trial(small.len(), compress(&small).len());
        }
        assert_eq!(codec.decision_for_len(small.len()), Some(false));
        // A mid-stream shape change lands in a fresh size bucket: the
        // chooser must probe again rather than inherit the old verdict.
        let big = compressible(64 << 10);
        assert_eq!(codec.plan(big.len()), CodecAction::Trial);
        codec.record_trial(big.len(), compress(&big).len());
        assert_eq!(codec.plan(big.len()), CodecAction::Trial);
        codec.record_trial(big.len(), compress(&big).len());
        assert_eq!(codec.decision_for_len(big.len()), Some(true));
        // The first class's sticky decision is untouched.
        assert_eq!(codec.decision_for_len(small.len()), Some(false));
        assert_eq!(codec.plan(small.len()), CodecAction::Skip);
    }

    #[test]
    fn reprobe_flips_on_content_drift_and_counts_switch() {
        let codec = AdaptiveCodec::with_config(2, 8);
        let raw = incompressible(4096, 3);
        for _ in 0..2 {
            codec.plan(raw.len());
            codec.record_trial(raw.len(), compress(&raw).len());
        }
        assert_eq!(codec.decision_for_len(raw.len()), Some(false));
        // Seven settled uses, then the eighth triggers the re-probe.
        for i in 0..7 {
            assert_eq!(codec.plan(raw.len()), CodecAction::Skip, "use {i}");
        }
        assert_eq!(codec.plan(raw.len()), CodecAction::Trial, "re-probe slot");
        // Same shape, new content: the stream turned compressible. The
        // re-probe sample must flip the decision and report the switch.
        let text = compressible(4096);
        let z = compress(&text);
        assert!(codec.record_trial(text.len(), z.len()), "flip reported as a switch");
        assert_eq!(codec.decision_for_len(raw.len()), Some(true));
        assert_eq!(codec.plan(raw.len()), CodecAction::Compress);
    }

    #[test]
    fn tiny_payloads_skip_without_probing() {
        let codec = AdaptiveCodec::new();
        for _ in 0..8 {
            assert_eq!(codec.plan(CODEC_MIN_LEN - 1), CodecAction::Skip);
        }
        assert_eq!(codec.decision_for_len(CODEC_MIN_LEN - 1), None, "no class state spent");
    }

    /// Byte identity across every decision the chooser can make — a
    /// same-size stream alternating compressible and incompressible
    /// content is the worst case (one class, flapping ratios): whatever
    /// the chooser decides, decode must return the exact input.
    #[test]
    fn round_trip_identity_across_all_decisions() {
        let codec = AdaptiveCodec::with_config(3, 4);
        for i in 0..64usize {
            let data = if i % 2 == 0 { compressible(4096) } else { incompressible(4096, i as u32) };
            let (wire, compressed, _switched) = drive(&codec, &data);
            let back = if compressed { decompress(&wire).unwrap() } else { wire };
            assert_eq!(back, data, "iteration {i}");
        }
    }

    #[test]
    fn hostile_inputs_rejected() {
        assert!(decompress(&[]).is_err());
        assert!(decompress(&[1, 0, 0]).is_err());
        // Match with distance beyond output.
        let mut bad = 4u32.to_le_bytes().to_vec();
        bad.extend_from_slice(&[TAG_MATCH, 4, 0, 9, 0]);
        assert!(decompress(&bad).is_err());
        // Bad token tag.
        let mut bad = 1u32.to_le_bytes().to_vec();
        bad.extend_from_slice(&[7, 0, 0]);
        assert!(decompress(&bad).is_err());
        // Output longer than the header claims.
        let mut bad = 1u32.to_le_bytes().to_vec();
        bad.extend_from_slice(&[TAG_LITERAL, 2, 0, b'a', b'b']);
        assert!(decompress(&bad).is_err());
        // Truncated literal body.
        let mut bad = 8u32.to_le_bytes().to_vec();
        bad.extend_from_slice(&[TAG_LITERAL, 8, 0, b'a']);
        assert!(decompress(&bad).is_err());
    }
}
