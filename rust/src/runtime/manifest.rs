//! Artifact manifest parsing (`artifacts/manifest.json`).

use crate::data::element::DType;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// One expected input of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct InputSpec {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

/// One AOT artifact entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactInfo {
    pub file: String,
    pub inputs: Vec<InputSpec>,
    pub sha256: String,
    pub bytes: usize,
}

/// Named parameter shape of the training model.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamShape {
    pub name: String,
    pub shape: Vec<usize>,
}

/// Parsed manifest: model hyperparameters + artifact table.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub format: String,
    pub model_vocab: usize,
    pub model_d_model: usize,
    pub model_seq: usize,
    pub model_batch: usize,
    pub param_count: usize,
    pub param_shapes: Vec<ParamShape>,
    pub vision_batch: usize,
    pub vision_hw: usize,
    pub vision_c: usize,
    pub nlp_batch: usize,
    pub nlp_seq: usize,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

fn usize_field(j: &Json, key: &str) -> Result<usize, String> {
    j.get(key).and_then(Json::as_usize).ok_or_else(|| format!("missing numeric field {key}"))
}

fn shape_of(j: &Json) -> Result<Vec<usize>, String> {
    j.as_arr()
        .ok_or("shape must be an array")?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| "bad dim".to_string()))
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let j = Json::parse(text)?;
        let format = j
            .get("format")
            .and_then(Json::as_str)
            .ok_or("missing format")?
            .to_string();
        if format != "hlo-text/1" {
            return Err(format!("unsupported manifest format {format}"));
        }
        let model = j.get("model").ok_or("missing model")?;
        let vision = j.get("vision").ok_or("missing vision")?;
        let nlp = j.get("nlp").ok_or("missing nlp")?;

        let param_shapes = model
            .get("param_shapes")
            .and_then(Json::as_arr)
            .ok_or("missing param_shapes")?
            .iter()
            .map(|p| {
                Ok(ParamShape {
                    name: p.get("name").and_then(Json::as_str).ok_or("param name")?.to_string(),
                    shape: shape_of(p.get("shape").ok_or("param shape")?)?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;

        let mut artifacts = BTreeMap::new();
        for (name, a) in j.get("artifacts").and_then(Json::as_obj).ok_or("missing artifacts")? {
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or("missing inputs")?
                .iter()
                .map(|i| {
                    let dname = i.get("dtype").and_then(Json::as_str).ok_or("dtype")?;
                    Ok(InputSpec {
                        dtype: DType::from_name(dname).ok_or_else(|| format!("bad dtype {dname}"))?,
                        shape: shape_of(i.get("shape").ok_or("shape")?)?,
                    })
                })
                .collect::<Result<Vec<_>, String>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    file: a.get("file").and_then(Json::as_str).ok_or("file")?.to_string(),
                    inputs,
                    sha256: a.get("sha256").and_then(Json::as_str).unwrap_or("").to_string(),
                    bytes: a.get("bytes").and_then(Json::as_usize).unwrap_or(0),
                },
            );
        }

        Ok(Manifest {
            format,
            model_vocab: usize_field(model, "vocab")?,
            model_d_model: usize_field(model, "d_model")?,
            model_seq: usize_field(model, "seq_len")?,
            model_batch: usize_field(model, "batch")?,
            param_count: usize_field(model, "param_count")?,
            param_shapes,
            vision_batch: usize_field(vision, "batch")?,
            vision_hw: usize_field(vision, "height")?,
            vision_c: usize_field(vision, "channels")?,
            nlp_batch: usize_field(nlp, "batch")?,
            nlp_seq: usize_field(nlp, "seq")?,
            artifacts,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "format": "hlo-text/1",
      "model": {"vocab": 256, "d_model": 8, "n_layers": 1, "n_heads": 2,
                "d_ff": 16, "seq_len": 4, "batch": 2, "param_count": 10,
                "param_shapes": [{"name": "embed", "shape": [256, 8]}]},
      "vision": {"batch": 4, "height": 8, "width": 8, "channels": 3},
      "nlp": {"batch": 4, "seq": 16},
      "artifacts": {
        "x": {"file": "x.hlo.txt",
              "inputs": [{"dtype": "f32", "shape": [2, 3]},
                         {"dtype": "i32", "shape": []}],
              "sha256": "ab", "bytes": 10}
      }
    }"#;

    #[test]
    fn parses_minimal_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.model_vocab, 256);
        assert_eq!(m.param_shapes[0].name, "embed");
        assert_eq!(m.param_shapes[0].shape, vec![256, 8]);
        let a = &m.artifacts["x"];
        assert_eq!(a.inputs[0], InputSpec { dtype: DType::F32, shape: vec![2, 3] });
        assert_eq!(a.inputs[1].shape, Vec::<usize>::new());
        assert_eq!(m.nlp_seq, 16);
    }

    #[test]
    fn rejects_wrong_format() {
        let bad = MINI.replace("hlo-text/1", "hlo-text/999");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = MINI.replace("\"f32\"", "\"q7\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = super::super::default_artifacts_dir().join("manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Manifest::parse(&text).unwrap();
            assert!(m.artifacts.contains_key("train_step"));
            assert!(m.artifacts.contains_key("preprocess_vision"));
            assert_eq!(m.param_shapes.len(), m.artifacts["train_step"].inputs.len() - 2);
        }
    }
}
