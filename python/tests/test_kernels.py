"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

hypothesis sweeps shapes and parameter ranges; every property asserts
allclose against kernels/ref.py. This is the CORE correctness signal for
the compute layer — if these pass, the HLO artifacts the Rust workers and
clients execute are numerically trustworthy.

(Absorbed the former test_kernel.py stub, which only restated this
docstring; kernel-vs-ref allclose coverage lives here.)
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import augment, ffn, ref

jax.config.update("jax_platforms", "cpu")

SET = settings(deadline=None, max_examples=25, derandomize=True)


def _imgs(rng, b, h, w, c):
    return rng.integers(0, 256, (b, h, w, c), dtype=np.uint8)


def _aug_params(rng, b):
    flip = rng.integers(0, 2, b).astype(np.float32)
    brightness = rng.normal(0.0, 0.2, b).astype(np.float32)
    contrast = rng.normal(1.0, 0.2, b).astype(np.float32)
    return flip, brightness, contrast


# ---------------------------------------------------------------------------
# augment
# ---------------------------------------------------------------------------


@SET
@given(
    b=st.integers(1, 9),
    h=st.sampled_from([1, 3, 4, 8, 16]),
    w=st.sampled_from([1, 2, 5, 8, 16]),
    c=st.sampled_from([1, 2, 3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_augment_matches_ref(b, h, w, c, seed):
    rng = np.random.default_rng(seed)
    img = _imgs(rng, b, h, w, c)
    flip, br, ct = _aug_params(rng, b)
    got = augment.augment(img, flip, br, ct)
    want = ref.augment_ref(jnp.asarray(img), jnp.asarray(flip), jnp.asarray(br), jnp.asarray(ct))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_augment_flip_is_involution():
    rng = np.random.default_rng(0)
    img = _imgs(rng, 4, 8, 8, 3)
    zeros = np.zeros(4, np.float32)
    ones = np.ones(4, np.float32)
    unit = np.ones(4, np.float32)
    plain = augment.augment(img, zeros, zeros, unit)
    flipped = augment.augment(img, ones, zeros, unit)
    np.testing.assert_allclose(np.asarray(flipped)[:, :, ::-1, :], plain, rtol=1e-5, atol=1e-6)


def test_augment_identity_params_is_pure_normalize():
    rng = np.random.default_rng(1)
    img = _imgs(rng, 2, 4, 4, 3)
    zeros = np.zeros(2, np.float32)
    unit = np.ones(2, np.float32)
    got = augment.augment(img, zeros, zeros, unit)
    x = img.astype(np.float32) / 255.0
    want = (x - np.asarray(ref.NORM_MEAN)) / np.asarray(ref.NORM_STD)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_augment_brightness_shifts_mean():
    rng = np.random.default_rng(2)
    img = _imgs(rng, 2, 8, 8, 3)
    zeros = np.zeros(2, np.float32)
    unit = np.ones(2, np.float32)
    base = np.asarray(augment.augment(img, zeros, zeros, unit))
    shifted = np.asarray(augment.augment(img, zeros, 0.5 * unit, unit))
    np.testing.assert_allclose(shifted, base + 0.5, rtol=1e-4, atol=1e-5)


def test_augment_zero_contrast_collapses_to_mean():
    rng = np.random.default_rng(3)
    img = _imgs(rng, 1, 8, 8, 3)
    zeros = np.zeros(1, np.float32)
    got = np.asarray(augment.augment(img, zeros, zeros, zeros))
    assert np.std(got) < 1e-5


def test_augment_output_dtype_and_shape():
    img = np.zeros((2, 4, 4, 3), np.uint8)
    z = np.zeros(2, np.float32)
    o = np.ones(2, np.float32)
    out = augment.augment(img, z, z, o)
    assert out.shape == img.shape and out.dtype == jnp.float32


def test_augment_per_sample_params_are_independent():
    rng = np.random.default_rng(4)
    img = _imgs(rng, 2, 4, 4, 3)
    # Sample 0 flipped, sample 1 not: sample 1 must equal the unflipped run.
    flip = np.array([1.0, 0.0], np.float32)
    z = np.zeros(2, np.float32)
    o = np.ones(2, np.float32)
    mixed = np.asarray(augment.augment(img, flip, z, o))
    plain = np.asarray(augment.augment(img, z, z, o))
    np.testing.assert_allclose(mixed[1], plain[1], rtol=1e-6)
    assert not np.allclose(mixed[0], plain[0])


# ---------------------------------------------------------------------------
# ffn
# ---------------------------------------------------------------------------


@SET
@given(
    n=st.integers(1, 70),
    d=st.sampled_from([4, 8, 16, 32]),
    f=st.sampled_from([8, 16, 64]),
    rb=st.sampled_from([8, 16, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ffn_matches_ref(n, d, f, rb, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    w1 = rng.normal(0, 0.2, (d, f)).astype(np.float32)
    b1 = rng.normal(0, 0.1, f).astype(np.float32)
    w2 = rng.normal(0, 0.2, (f, d)).astype(np.float32)
    b2 = rng.normal(0, 0.1, d).astype(np.float32)
    got = ffn.ffn(x, w1, b1, w2, b2, row_block=rb)
    want = ref.ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_ffn_gelu_grad_matches_autodiff():
    x = jnp.linspace(-4, 4, 101)
    got = ffn._gelu_grad(x)
    want = jax.vmap(jax.grad(ref.gelu_ref))(x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_ffn_trainable_grads_match_ref_grads():
    rng = np.random.default_rng(7)
    x = rng.normal(0, 1, (16, 8)).astype(np.float32)
    w1 = rng.normal(0, 0.3, (8, 16)).astype(np.float32)
    b1 = rng.normal(0, 0.1, 16).astype(np.float32)
    w2 = rng.normal(0, 0.3, (16, 8)).astype(np.float32)
    b2 = rng.normal(0, 0.1, 8).astype(np.float32)

    def loss_kernel(args):
        return jnp.sum(ffn.ffn_trainable(*args) ** 2)

    def loss_ref(args):
        return jnp.sum(ref.ffn_ref(*args) ** 2)

    args = (x, w1, b1, w2, b2)
    gk = jax.grad(loss_kernel)(args)
    gr = jax.grad(loss_ref)(args)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=1e-4)


def test_ffn_row_padding_does_not_leak():
    # n not a multiple of row_block: padded rows must not affect real rows.
    rng = np.random.default_rng(8)
    d, f = 8, 16
    w1 = rng.normal(0, 0.2, (d, f)).astype(np.float32)
    b1 = np.zeros(f, np.float32)
    w2 = rng.normal(0, 0.2, (f, d)).astype(np.float32)
    b2 = np.zeros(d, np.float32)
    x = rng.normal(0, 1, (10, d)).astype(np.float32)
    whole = np.asarray(ffn.ffn(x, w1, b1, w2, b2, row_block=8))
    for i in range(10):
        row = np.asarray(ffn.ffn(x[i : i + 1], w1, b1, w2, b2, row_block=8))
        np.testing.assert_allclose(whole[i : i + 1], row, rtol=1e-4, atol=1e-5)


def test_ffn_vmem_estimate_is_positive_and_monotone():
    small = ffn.vmem_bytes(8, 16, 32)
    big = ffn.vmem_bytes(128, 128, 512)
    assert 0 < small < big
    # e2e config must fit VMEM (~16 MB) with 2x double-buffer headroom.
    assert ffn.vmem_bytes(128, 128, 512) * 2 < 16 * 1024 * 1024


def test_augment_vmem_estimate_fits_vmem_for_imagenet_tile():
    assert augment.vmem_bytes(224, 224, 3) * 2 < 16 * 1024 * 1024
