//! Serializable dataset graphs.
//!
//! A pipeline is a linear chain of [`Node`]s rooted at a source — the same
//! shape tf.data graphs take after functionalization. Clients serialize a
//! [`GraphDef`] and register it with the dispatcher; the dispatcher ships
//! it to every worker (§3.1). UDFs are referenced *by name* and resolved
//! against the worker's [`super::udf::UdfRegistry`].

use crate::storage::dataset::DatasetSpec;
use crate::wire::{Decode, Encode, Reader, WireError, WireResult, Writer};

/// One pipeline stage.
#[derive(Debug, Clone, PartialEq)]
pub enum Node {
    /// Sharded vision dataset source (yields `(pixels u8[H,W,C], label u32)`).
    SourceVision { spec: DatasetSpec },
    /// Sharded text dataset source (yields `(tokens u32[len], label u32)`).
    SourceText { spec: DatasetSpec },
    /// Synthetic integer range source for tests (yields `(i64 scalar,)`).
    SourceRange { n: u64 },
    /// Apply a named UDF to each element. `parallelism` 0 means AUTOTUNE.
    Map { udf: String, parallelism: u32 },
    /// Keep elements for which the named predicate UDF returns nonzero.
    Filter { udf: String },
    /// Uniform shuffle over a sliding buffer.
    Shuffle { buffer: u32, seed: u64 },
    /// Fixed-size batch by stacking same-shaped tensors.
    Batch { size: u32, drop_remainder: bool },
    /// Batch of variable-length rank-1 tensors, padded to the longest
    /// sample in the batch (the paper's NLP batching mode).
    PaddedBatch { size: u32, drop_remainder: bool },
    /// Background prefetch buffer.
    Prefetch { n: u32 },
    /// Repeat the upstream `n` times; 0 = indefinitely.
    Repeat { n: u32 },
    /// At most `n` elements.
    Take { n: u64 },
    /// Drop the first `n` elements.
    Skip { n: u64 },
    /// Materialize upstream on first pass, replay thereafter.
    Cache,
    /// Read `cycle` source shards round-robin (file-level interleave).
    Interleave { cycle: u32 },
    /// Group samples into per-length-bucket batches (Fig. 7 line 1).
    /// Bucket `i` holds lengths in `(boundaries[i-1], boundaries[i]]`;
    /// a final bucket catches everything above the last boundary.
    BucketBySequenceLength { boundaries: Vec<u32>, batch_size: u32 },
    /// Emit `window_size` consecutive elements sharing a bucket key
    /// (Fig. 7 line 2; the subsequent `flat_map` is folded in).
    GroupByWindow { window_size: u32 },
    /// Identity marker kept for API fidelity with Fig. 7 line 3.
    FlatMap,
}

impl Node {
    pub fn is_source(&self) -> bool {
        matches!(
            self,
            Node::SourceVision { .. } | Node::SourceText { .. } | Node::SourceRange { .. }
        )
    }

    /// Short operator name for logs and metrics.
    pub fn op_name(&self) -> &'static str {
        match self {
            Node::SourceVision { .. } => "source_vision",
            Node::SourceText { .. } => "source_text",
            Node::SourceRange { .. } => "source_range",
            Node::Map { .. } => "map",
            Node::Filter { .. } => "filter",
            Node::Shuffle { .. } => "shuffle",
            Node::Batch { .. } => "batch",
            Node::PaddedBatch { .. } => "padded_batch",
            Node::Prefetch { .. } => "prefetch",
            Node::Repeat { .. } => "repeat",
            Node::Take { .. } => "take",
            Node::Skip { .. } => "skip",
            Node::Cache => "cache",
            Node::Interleave { .. } => "interleave",
            Node::BucketBySequenceLength { .. } => "bucket_by_sequence_length",
            Node::GroupByWindow { .. } => "group_by_window",
            Node::FlatMap => "flat_map",
        }
    }
}

impl Encode for Node {
    fn encode(&self, w: &mut Writer) {
        match self {
            Node::SourceVision { spec } => {
                w.put_u8(0);
                spec.encode(w);
            }
            Node::SourceText { spec } => {
                w.put_u8(1);
                spec.encode(w);
            }
            Node::SourceRange { n } => {
                w.put_u8(2);
                w.put_u64(*n);
            }
            Node::Map { udf, parallelism } => {
                w.put_u8(3);
                udf.encode(w);
                w.put_u32(*parallelism);
            }
            Node::Filter { udf } => {
                w.put_u8(4);
                udf.encode(w);
            }
            Node::Shuffle { buffer, seed } => {
                w.put_u8(5);
                w.put_u32(*buffer);
                w.put_u64(*seed);
            }
            Node::Batch { size, drop_remainder } => {
                w.put_u8(6);
                w.put_u32(*size);
                drop_remainder.encode(w);
            }
            Node::PaddedBatch { size, drop_remainder } => {
                w.put_u8(7);
                w.put_u32(*size);
                drop_remainder.encode(w);
            }
            Node::Prefetch { n } => {
                w.put_u8(8);
                w.put_u32(*n);
            }
            Node::Repeat { n } => {
                w.put_u8(9);
                w.put_u32(*n);
            }
            Node::Take { n } => {
                w.put_u8(10);
                w.put_u64(*n);
            }
            Node::Skip { n } => {
                w.put_u8(11);
                w.put_u64(*n);
            }
            Node::Cache => w.put_u8(12),
            Node::Interleave { cycle } => {
                w.put_u8(13);
                w.put_u32(*cycle);
            }
            Node::BucketBySequenceLength { boundaries, batch_size } => {
                w.put_u8(14);
                boundaries.encode(w);
                w.put_u32(*batch_size);
            }
            Node::GroupByWindow { window_size } => {
                w.put_u8(15);
                w.put_u32(*window_size);
            }
            Node::FlatMap => w.put_u8(16),
        }
    }
}

impl Decode for Node {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        Ok(match r.get_u8()? {
            0 => Node::SourceVision { spec: DatasetSpec::decode(r)? },
            1 => Node::SourceText { spec: DatasetSpec::decode(r)? },
            2 => Node::SourceRange { n: r.get_u64()? },
            3 => Node::Map { udf: String::decode(r)?, parallelism: r.get_u32()? },
            4 => Node::Filter { udf: String::decode(r)? },
            5 => Node::Shuffle { buffer: r.get_u32()?, seed: r.get_u64()? },
            6 => Node::Batch { size: r.get_u32()?, drop_remainder: bool::decode(r)? },
            7 => Node::PaddedBatch { size: r.get_u32()?, drop_remainder: bool::decode(r)? },
            8 => Node::Prefetch { n: r.get_u32()? },
            9 => Node::Repeat { n: r.get_u32()? },
            10 => Node::Take { n: r.get_u64()? },
            11 => Node::Skip { n: r.get_u64()? },
            12 => Node::Cache,
            13 => Node::Interleave { cycle: r.get_u32()? },
            14 => Node::BucketBySequenceLength {
                boundaries: Vec::<u32>::decode(r)?,
                batch_size: r.get_u32()?,
            },
            15 => Node::GroupByWindow { window_size: r.get_u32()? },
            16 => Node::FlatMap,
            tag => return Err(WireError::BadTag { tag, ty: "Node" }),
        })
    }
}

/// A complete pipeline definition: a source followed by transformations.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct GraphDef {
    pub nodes: Vec<Node>,
}

impl Encode for GraphDef {
    fn encode(&self, w: &mut Writer) {
        crate::wire::encode_vec(&self.nodes, w);
    }
}

impl Decode for GraphDef {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        Ok(GraphDef { nodes: crate::wire::decode_vec(r)? })
    }
}

impl GraphDef {
    /// Validate structural invariants: exactly one source, at the front.
    pub fn validate(&self) -> Result<(), String> {
        match self.nodes.first() {
            Some(n) if n.is_source() => {}
            Some(n) => return Err(format!("first node must be a source, got {}", n.op_name())),
            None => return Err("empty graph".into()),
        }
        if self.nodes.iter().skip(1).any(|n| n.is_source()) {
            return Err("multiple sources".into());
        }
        for n in &self.nodes {
            match n {
                Node::Batch { size, .. } | Node::PaddedBatch { size, .. } if *size == 0 => {
                    return Err("batch size 0".into())
                }
                Node::BucketBySequenceLength { boundaries, batch_size } => {
                    if *batch_size == 0 {
                        return Err("bucket batch size 0".into());
                    }
                    if boundaries.windows(2).any(|w| w[0] >= w[1]) {
                        return Err("bucket boundaries must be strictly increasing".into());
                    }
                }
                Node::GroupByWindow { window_size } if *window_size == 0 => {
                    return Err("window size 0".into())
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Canonical structural fingerprint: jobs sharing a fingerprint can
    /// share ephemeral data (§3.5 requires "identical input pipelines").
    ///
    /// Truncation of [`GraphDef::fingerprint_full`]; see there for the
    /// canonicalization rules.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint_with_udfs(&|_| None)
    }

    /// [`GraphDef::fingerprint`] with UDF *body* digests mixed in: a
    /// referenced UDF name resolving to a digest contributes
    /// `name ++ digest`, so re-implementing a UDF under the same name
    /// changes the fingerprint and blocks accidental sharing.
    pub fn fingerprint_with_udfs(&self, digest_of: &dyn Fn(&str) -> Option<u64>) -> u64 {
        let digest = self.fingerprint_full(digest_of);
        u64::from_le_bytes(digest[..8].try_into().unwrap())
    }

    /// Full 256-bit canonical fingerprint.
    ///
    /// The hash walks the graph and feeds each node's *semantic identity*
    /// — operator name plus data-affecting parameters — through the
    /// in-tree SHA-256, with explicit domain separation (version prefix,
    /// per-node framing, length-prefixed fields). Deliberately **not** a
    /// hash of the wire encoding, so:
    ///
    /// * it is stable across wire-format evolution and registration
    ///   order (two clients registering the same pipeline always collide),
    /// * purely *performance* attributes are excluded: `Map.parallelism`
    ///   and `Prefetch` tune throughput without changing the produced
    ///   stream, so pipelines differing only in tuning still share data,
    /// * it stays sensitive to everything that changes the data: op
    ///   parameters (batch sizes, shuffle seed, bucket boundaries…), UDF
    ///   names (and bodies, via `digest_of`), and the source file list.
    pub fn fingerprint_full(&self, digest_of: &dyn Fn(&str) -> Option<u64>) -> [u8; 32] {
        let mut w = Writer::new();
        w.put_bytes(b"tfdatasvc.pipeline-fingerprint.v1");
        let hash_udf = |w: &mut Writer, name: &str| {
            w.put_bytes(name.as_bytes());
            match digest_of(name) {
                Some(d) => {
                    w.put_u8(1);
                    w.put_u64(d);
                }
                None => w.put_u8(0),
            }
        };
        let hash_spec = |w: &mut Writer, spec: &DatasetSpec| {
            w.put_bytes(spec.prefix.as_bytes());
            w.put_u32(spec.shards.len() as u32);
            for s in &spec.shards {
                w.put_bytes(s.as_bytes());
            }
            w.put_u64(spec.samples_per_shard as u64);
            w.put_u64(spec.total_samples as u64);
        };
        for node in &self.nodes {
            // Performance-only: no effect on the element stream.
            if matches!(node, Node::Prefetch { .. }) {
                continue;
            }
            w.put_bytes(node.op_name().as_bytes());
            match node {
                Node::SourceVision { spec } | Node::SourceText { spec } => hash_spec(&mut w, spec),
                Node::SourceRange { n } => w.put_u64(*n),
                // `parallelism` reorders in-flight execution, not output
                // content (maps are element-wise): excluded.
                Node::Map { udf, parallelism: _ } => hash_udf(&mut w, udf),
                Node::Filter { udf } => hash_udf(&mut w, udf),
                Node::Shuffle { buffer, seed } => {
                    w.put_u32(*buffer);
                    w.put_u64(*seed);
                }
                Node::Batch { size, drop_remainder } | Node::PaddedBatch { size, drop_remainder } => {
                    w.put_u32(*size);
                    w.put_u8(*drop_remainder as u8);
                }
                Node::Prefetch { .. } => unreachable!("skipped above"),
                Node::Repeat { n } => w.put_u32(*n),
                Node::Take { n } | Node::Skip { n } => w.put_u64(*n),
                Node::Cache | Node::FlatMap => {}
                Node::Interleave { cycle } => w.put_u32(*cycle),
                Node::BucketBySequenceLength { boundaries, batch_size } => {
                    w.put_u32(boundaries.len() as u32);
                    for b in boundaries {
                        w.put_u32(*b);
                    }
                    w.put_u32(*batch_size);
                }
                Node::GroupByWindow { window_size } => w.put_u32(*window_size),
            }
        }
        crate::util::sha256::sha256(w.as_slice())
    }
}

/// Fluent builder mirroring the Python tf.data API (Fig. 4 / Fig. 7).
#[derive(Debug, Clone, Default)]
pub struct PipelineBuilder {
    nodes: Vec<Node>,
}

impl PipelineBuilder {
    pub fn source_vision(spec: DatasetSpec) -> Self {
        PipelineBuilder { nodes: vec![Node::SourceVision { spec }] }
    }

    pub fn source_text(spec: DatasetSpec) -> Self {
        PipelineBuilder { nodes: vec![Node::SourceText { spec }] }
    }

    pub fn source_range(n: u64) -> Self {
        PipelineBuilder { nodes: vec![Node::SourceRange { n }] }
    }

    pub fn map(mut self, udf: &str) -> Self {
        self.nodes.push(Node::Map { udf: udf.into(), parallelism: 1 });
        self
    }

    pub fn map_parallel(mut self, udf: &str, parallelism: u32) -> Self {
        self.nodes.push(Node::Map { udf: udf.into(), parallelism });
        self
    }

    /// AUTOTUNE parallelism.
    pub fn map_autotune(mut self, udf: &str) -> Self {
        self.nodes.push(Node::Map { udf: udf.into(), parallelism: 0 });
        self
    }

    pub fn filter(mut self, udf: &str) -> Self {
        self.nodes.push(Node::Filter { udf: udf.into() });
        self
    }

    pub fn shuffle(mut self, buffer: u32, seed: u64) -> Self {
        self.nodes.push(Node::Shuffle { buffer, seed });
        self
    }

    pub fn batch(mut self, size: u32) -> Self {
        self.nodes.push(Node::Batch { size, drop_remainder: true });
        self
    }

    pub fn batch_partial(mut self, size: u32) -> Self {
        self.nodes.push(Node::Batch { size, drop_remainder: false });
        self
    }

    pub fn padded_batch(mut self, size: u32) -> Self {
        self.nodes.push(Node::PaddedBatch { size, drop_remainder: true });
        self
    }

    pub fn prefetch(mut self, n: u32) -> Self {
        self.nodes.push(Node::Prefetch { n });
        self
    }

    pub fn repeat(mut self, n: u32) -> Self {
        self.nodes.push(Node::Repeat { n });
        self
    }

    pub fn take(mut self, n: u64) -> Self {
        self.nodes.push(Node::Take { n });
        self
    }

    pub fn skip(mut self, n: u64) -> Self {
        self.nodes.push(Node::Skip { n });
        self
    }

    pub fn cache(mut self) -> Self {
        self.nodes.push(Node::Cache);
        self
    }

    pub fn interleave(mut self, cycle: u32) -> Self {
        self.nodes.push(Node::Interleave { cycle });
        self
    }

    pub fn bucket_by_sequence_length(mut self, boundaries: Vec<u32>, batch_size: u32) -> Self {
        self.nodes.push(Node::BucketBySequenceLength { boundaries, batch_size });
        self
    }

    pub fn group_by_window(mut self, window_size: u32) -> Self {
        self.nodes.push(Node::GroupByWindow { window_size });
        self
    }

    pub fn flat_map(mut self) -> Self {
        self.nodes.push(Node::FlatMap);
        self
    }

    pub fn build(self) -> GraphDef {
        GraphDef { nodes: self.nodes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_spec() -> DatasetSpec {
        DatasetSpec {
            prefix: "d".into(),
            shards: vec!["d/shard-00000".into()],
            samples_per_shard: 4,
            total_samples: 4,
        }
    }

    #[test]
    fn builder_produces_valid_graph() {
        let g = PipelineBuilder::source_vision(demo_spec())
            .map_parallel("vision.normalize", 4)
            .shuffle(128, 7)
            .batch(32)
            .prefetch(2)
            .build();
        g.validate().unwrap();
        assert_eq!(g.nodes.len(), 5);
    }

    #[test]
    fn graph_wire_roundtrip_all_nodes() {
        let g = GraphDef {
            nodes: vec![
                Node::SourceText { spec: demo_spec() },
                Node::Map { udf: "a".into(), parallelism: 0 },
                Node::Filter { udf: "p".into() },
                Node::Shuffle { buffer: 16, seed: 3 },
                Node::Batch { size: 4, drop_remainder: true },
                Node::PaddedBatch { size: 8, drop_remainder: false },
                Node::Prefetch { n: 2 },
                Node::Repeat { n: 0 },
                Node::Take { n: 100 },
                Node::Skip { n: 5 },
                Node::Cache,
                Node::Interleave { cycle: 4 },
                Node::BucketBySequenceLength { boundaries: vec![64, 128], batch_size: 16 },
                Node::GroupByWindow { window_size: 2 },
                Node::FlatMap,
            ],
        };
        let back = GraphDef::from_bytes(&g.to_bytes()).unwrap();
        assert_eq!(g, back);
    }

    #[test]
    fn validate_rejects_bad_graphs() {
        assert!(GraphDef::default().validate().is_err());
        let no_source = GraphDef { nodes: vec![Node::Cache] };
        assert!(no_source.validate().is_err());
        let two_sources = GraphDef {
            nodes: vec![Node::SourceRange { n: 1 }, Node::SourceRange { n: 2 }],
        };
        assert!(two_sources.validate().is_err());
        let zero_batch = GraphDef {
            nodes: vec![Node::SourceRange { n: 1 }, Node::Batch { size: 0, drop_remainder: true }],
        };
        assert!(zero_batch.validate().is_err());
        let bad_bounds = GraphDef {
            nodes: vec![
                Node::SourceRange { n: 1 },
                Node::BucketBySequenceLength { boundaries: vec![128, 64], batch_size: 4 },
            ],
        };
        assert!(bad_bounds.validate().is_err());
    }

    #[test]
    fn fingerprint_distinguishes_pipelines() {
        let a = PipelineBuilder::source_range(10).batch(2).build();
        let b = PipelineBuilder::source_range(10).batch(4).build();
        let a2 = PipelineBuilder::source_range(10).batch(2).build();
        assert_eq!(a.fingerprint(), a2.fingerprint());
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn fingerprint_ignores_performance_attrs() {
        // Map parallelism and prefetch depth tune throughput, not content:
        // pipelines differing only there must share a fingerprint (§3.5
        // sharing should not be defeated by per-job autotune settings).
        let a = PipelineBuilder::source_range(100)
            .map_parallel("vision.normalize", 4)
            .batch(8)
            .prefetch(2)
            .build();
        let b = PipelineBuilder::source_range(100)
            .map_autotune("vision.normalize")
            .batch(8)
            .prefetch(64)
            .build();
        let c = PipelineBuilder::source_range(100)
            .map_parallel("vision.normalize", 4)
            .batch(8)
            .build(); // no prefetch at all
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn fingerprint_sensitive_to_semantic_params() {
        let base = || PipelineBuilder::source_range(100).shuffle(64, 7).batch(8);
        let a = base().build();
        // One op param changed -> different hash.
        let other_seed = PipelineBuilder::source_range(100).shuffle(64, 8).batch(8).build();
        assert_ne!(a.fingerprint(), other_seed.fingerprint());
        let other_buf = PipelineBuilder::source_range(100).shuffle(32, 7).batch(8).build();
        assert_ne!(a.fingerprint(), other_buf.fingerprint());
        assert_ne!(a.fingerprint(), base().take(5).build().fingerprint());
        // UDF name changes the hash.
        let m1 = base().map("vision.normalize").build();
        let m2 = base().map("vision.augment").build();
        assert_ne!(m1.fingerprint(), m2.fingerprint());
        // drop_remainder is semantic (partial batch present or not).
        let p = PipelineBuilder::source_range(100).batch_partial(8).build();
        let f = PipelineBuilder::source_range(100).batch(8).build();
        assert_ne!(p.fingerprint(), f.fingerprint());
    }

    #[test]
    fn fingerprint_sensitive_to_source_file_list() {
        let mk = |shards: Vec<String>| {
            let total = shards.len() * 4;
            PipelineBuilder::source_vision(DatasetSpec {
                prefix: "d".into(),
                shards,
                samples_per_shard: 4,
                total_samples: total,
            })
            .batch(2)
            .build()
        };
        let a = mk(vec!["d/s0".into(), "d/s1".into()]);
        let b = mk(vec!["d/s0".into(), "d/s2".into()]);
        let c = mk(vec!["d/s0".into(), "d/s1".into(), "d/s2".into()]);
        assert_ne!(a.fingerprint(), b.fingerprint(), "different file");
        assert_ne!(a.fingerprint(), c.fingerprint(), "extra file");
        assert_eq!(a.fingerprint(), mk(vec!["d/s0".into(), "d/s1".into()]).fingerprint());
    }

    #[test]
    fn fingerprint_sensitive_to_udf_body_digest() {
        let g = PipelineBuilder::source_range(10).map("custom.op").batch(2).build();
        let plain = g.fingerprint();
        let v1 = g.fingerprint_with_udfs(&|name| (name == "custom.op").then_some(0x1111));
        let v2 = g.fingerprint_with_udfs(&|name| (name == "custom.op").then_some(0x2222));
        assert_ne!(v1, v2, "UDF body change must change the hash");
        assert_ne!(plain, v1, "digested vs undigested differ");
        // Digests for names the graph never references are inert.
        let unrelated = g.fingerprint_with_udfs(&|name| (name == "other.op").then_some(0x3333));
        assert_eq!(plain, unrelated);
    }

    #[test]
    fn fingerprint_stable_across_wire_roundtrip() {
        let g = PipelineBuilder::source_range(50)
            .map("vision.normalize")
            .shuffle(16, 3)
            .batch(4)
            .build();
        let back = GraphDef::from_bytes(&g.to_bytes()).unwrap();
        assert_eq!(g.fingerprint(), back.fingerprint());
        assert_eq!(g.fingerprint_full(&|_| None), back.fingerprint_full(&|_| None));
    }
}
