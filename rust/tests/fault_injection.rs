//! Deterministic fault-injection e2e suite for the coordinated round
//! plane (§3.4 × §3.6): dispatcher kill+restore mid-epoch (journaled
//! round leases), owner kill → lease reassignment → revival re-balance,
//! and seeded random kill/revive/restart schedules. The CI hygiene job
//! runs this suite under several fixed seeds (`TFDATASVC_FAULT_SEED`)
//! with a hard timeout; every blocking wait below also carries its own
//! deadline so a hang fails fast instead of wedging the runner.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use common::{
    coord_cfg, fault_seed, journal_path, seeded_fault_plan, start_ticker, Cluster, FaultEvent,
};
use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::orchestrator::failure::{FailureConfig, FailureInjector};
use tfdatasvc::orchestrator::Cell;
use tfdatasvc::service::client::DistributedIter;
use tfdatasvc::service::dispatcher::DispatcherConfig;
use tfdatasvc::service::proto::{SharingMode, ShardingPolicy};
use tfdatasvc::service::journal::Journal;
use tfdatasvc::service::spill::{data_key, manifest_key, SpillConfig, SpillPolicy};
use tfdatasvc::service::visitation::RoundTracker;
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::storage::ObjectStore;
use tfdatasvc::util::crc32::{crc32, crc32_scalar, Hasher};
use tfdatasvc::util::rng::Rng;
use tfdatasvc::wire::{compress, decompress, AdaptiveCodec, CodecAction};

/// Consume `n` rounds, feeding the tracker (signature constant: a single
/// consumer only checks the exactly-once-per-slot and floor halves).
fn drain_rounds(it: &mut DistributedIter, tracker: &mut RoundTracker, rounds: &mut u64, n: u64) {
    for _ in 0..n {
        let e = it.next().expect("round fetch failed").expect("stream ended early");
        assert!(!e.tensors.is_empty());
        tracker.observe(*rounds, 0, 0);
        *rounds += 1;
    }
}

/// Consume `n` rounds for one consumer slot of a multi-consumer job,
/// labeling tracker entries with the slot's own round cursor so the
/// exactly-once-per-(round, slot) half of the report stays meaningful.
fn drain_slot(
    it: &mut DistributedIter,
    tracker: &mut RoundTracker,
    cursor: &mut u64,
    slot: usize,
    n: u64,
) {
    for _ in 0..n {
        let e = it.next().expect("round fetch failed").expect("stream ended early");
        assert!(!e.tensors.is_empty());
        tracker.observe(*cursor, slot, 0);
        *cursor += 1;
    }
}

/// Poll `probe` until it returns true or `what` times out.
fn wait_until(deadline: Instant, what: &str, mut probe: impl FnMut() -> bool) {
    while !probe() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Acceptance: a coordinated job with 3 workers survives a mid-epoch
/// dispatcher kill+restore — rounds resume at the journaled floor and
/// exactly-once-per-slot holds — and a killed-then-revived owner regains
/// its residues within one heartbeat+hysteresis window (lease counters
/// asserted on both dispatcher and worker).
#[test]
fn coordinated_job_survives_dispatcher_restart_and_owner_revival() {
    let dcfg = DispatcherConfig {
        // Generous vs the ~max heartbeat gap across the dispatcher's own
        // restart (downtime + pool retry budget + interval), so the
        // restart itself cannot spuriously fail workers.
        worker_timeout: Duration::from_millis(800),
        journal_path: Some(journal_path("coord-restart")),
        revival_hysteresis: Duration::from_millis(200),
        ..Default::default()
    };
    let cluster = Cluster::with_config(3, dcfg);
    let _ticker = start_ticker(&cluster, Duration::from_millis(50));

    // A long source so the epoch cannot end mid-test.
    let graph = PipelineBuilder::source_range(100_000).build();
    let client = cluster.client();
    let mut it = client.distribute(&graph, coord_cfg("coord-restart", 1, 0)).unwrap();

    let mut tracker = RoundTracker::new();
    let mut rounds = 0u64;
    drain_rounds(&mut it, &mut tracker, &mut rounds, 6);

    // Mid-epoch dispatcher kill + journal-backed restore at the same
    // (stable) address: worker_order and the lease table replay, so the
    // job stays routable and rounds resume at the floor the first
    // post-restart heartbeats report.
    cluster.restart_dispatcher(Duration::from_millis(300));
    tracker.set_floor(rounds);
    drain_rounds(&mut it, &mut tracker, &mut rounds, 6);

    // Kill one owner: after the lease expires, its residues move to the
    // survivors and rounds keep flowing.
    cluster.kill_worker(2);
    wait_until(Instant::now() + Duration::from_secs(10), "lease reassignment", || {
        cluster.dispatcher().metrics().counter("dispatcher/round_leases_reassigned").get() >= 1
    });
    drain_rounds(&mut it, &mut tracker, &mut rounds, 6);

    // Revive the owner behind its stable address: one registration +
    // hysteresis window later its home residues re-balance back.
    cluster.revive_worker(2);
    let revived_at = Instant::now();
    wait_until(revived_at + Duration::from_secs(10), "revival re-balance", || {
        cluster.dispatcher().metrics().counter("dispatcher/round_leases_rebalanced").get() >= 1
    });
    // Generous sanity bound on "within one heartbeat+hysteresis window":
    // registration (immediate) + 200 ms hysteresis + 50 ms tick + one
    // 100 ms heartbeat, with scheduler slack.
    assert!(
        revived_at.elapsed() < Duration::from_secs(5),
        "re-balance took {:?}",
        revived_at.elapsed()
    );
    wait_until(Instant::now() + Duration::from_secs(10), "revived owner lease adoption", || {
        cluster
            .with_worker(2, |w| w.metrics().counter("worker/round_leases_updated").get() >= 1)
            .unwrap_or(false)
    });
    // The revived owner serves again: keep draining well past the
    // prefetch window so rounds of its residue class must flow through it.
    drain_rounds(&mut it, &mut tracker, &mut rounds, 12);

    let report = tracker.report();
    assert_eq!(report.duplicate_deliveries, 0, "{report:?}");
    assert_eq!(report.below_floor_deliveries, 0, "{report:?}");
    assert_eq!(rounds, 30, "rounds kept flowing through every fault");
    it.release();
}

/// Seeded schedule: random kill/revive/dispatcher-restart faults at
/// scripted consumer-progress points. Invariants: rounds never stall
/// past the deadline, no (consumer, round) slot is delivered twice, and
/// nothing below a restart floor is re-served. Reproducible: the
/// schedule is a pure function of the seed.
#[test]
fn seeded_fault_schedule_keeps_round_plane_consistent() {
    let seed = fault_seed(0x5eed_0001);
    let num_workers = 3usize;
    let steps = 48u64;
    let dcfg = DispatcherConfig {
        journal_path: Some(journal_path(&format!("fault-sched-{seed}"))),
        worker_timeout: Duration::from_millis(600),
        revival_hysteresis: Duration::from_millis(100),
        ..Default::default()
    };
    let cluster = Cluster::with_config(num_workers, dcfg);
    let _ticker = start_ticker(&cluster, Duration::from_millis(40));
    let plan = seeded_fault_plan(seed, num_workers, steps);
    assert!(!plan.is_empty(), "seed {seed} produced an empty schedule");

    let graph = PipelineBuilder::source_range(1_000_000).build();
    let client = cluster.client();
    let mut it = client.distribute(&graph, coord_cfg(&format!("fault-{seed}"), 1, 0)).unwrap();

    let mut tracker = RoundTracker::new();
    let mut next_event = 0usize;
    let deadline = Instant::now() + Duration::from_secs(180);
    for round in 0..steps {
        while next_event < plan.len() && plan[next_event].at_step <= round {
            match plan[next_event].event {
                FaultEvent::KillWorker(i) => cluster.kill_worker(i),
                FaultEvent::ReviveWorker(i) => cluster.revive_worker(i),
                FaultEvent::RestartDispatcher => {
                    cluster.restart_dispatcher(Duration::from_millis(200));
                    tracker.set_floor(round);
                }
            }
            next_event += 1;
        }
        let e = it.next().expect("round fetch failed under faults").expect("stream ended early");
        assert!(!e.tensors.is_empty());
        tracker.observe(round, 0, 0);
        assert!(Instant::now() < deadline, "fault schedule run exceeded its deadline");
    }
    let report = tracker.report();
    assert_eq!(report.duplicate_deliveries, 0, "{report:?}");
    assert_eq!(report.below_floor_deliveries, 0, "{report:?}");
    assert_eq!(report.rounds_seen as u64, steps);
    it.release();
}

/// The schedule generator really is deterministic per seed (the property
/// the CI seed matrix relies on) and never plans an impossible event
/// (kill of a down worker, revive of an up one, killing the last worker).
#[test]
fn seeded_fault_plan_is_deterministic_and_well_formed() {
    for seed in [1u64, 17, 42, 0x5eed_0001] {
        let a = seeded_fault_plan(seed, 3, 64);
        let b = seeded_fault_plan(seed, 3, 64);
        assert_eq!(a.len(), b.len(), "seed {seed}");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_step, y.at_step);
            assert_eq!(x.event, y.event);
        }
        let mut up = vec![true; 3];
        let mut restarts = 0;
        let mut last_step = 0;
        for f in &a {
            assert!(f.at_step >= last_step, "schedule is ordered");
            last_step = f.at_step;
            match f.event {
                FaultEvent::KillWorker(i) => {
                    assert!(up[i], "kill of a down worker");
                    up[i] = false;
                    assert!(up.iter().any(|&u| u), "killed the last worker");
                }
                FaultEvent::ReviveWorker(i) => {
                    assert!(!up[i], "revive of an up worker");
                    up[i] = true;
                }
                FaultEvent::RestartDispatcher => restarts += 1,
            }
        }
        assert!(up.iter().all(|&u| u), "every kill is paired with a revive");
        assert!(restarts <= 1);
    }
}

/// Tentpole regression: a consumer slot replaced after its lease expires
/// skips forward over rounds its crashed predecessor already consumed —
/// metered on `client/rounds_skipped_forward` — instead of dying on the
/// formerly-terminal "round already consumed" error. The surviving slot
/// and the predecessor must never skip.
#[test]
fn replacement_consumer_after_lease_expiry_skips_forward() {
    let dcfg = DispatcherConfig {
        worker_timeout: Duration::from_millis(600),
        ..Default::default()
    };
    let cluster = Cluster::with_config(3, dcfg);
    let _ticker = start_ticker(&cluster, Duration::from_millis(50));
    let graph = PipelineBuilder::source_range(1_000_000).build();

    let client_a = cluster.client();
    let client_b = cluster.client();
    let mut it_a = client_a.distribute(&graph, coord_cfg("replace", 2, 0)).unwrap();
    let mut it_b = client_b.distribute(&graph, coord_cfg("replace", 2, 1)).unwrap();

    let mut tracker = RoundTracker::new();
    let (mut a_rounds, mut b_rounds) = (0u64, 0u64);
    for _ in 0..8 {
        drain_slot(&mut it_a, &mut tracker, &mut a_rounds, 0, 1);
        drain_slot(&mut it_b, &mut tracker, &mut b_rounds, 1, 1);
    }

    // Trainer B crashes silently: no ReleaseJob, heartbeats just stop.
    it_b.abandon();
    // Let the slot's progress entry age out (> worker_timeout + a tick):
    // the replacement must then activate at the epoch floor — round 0 —
    // rather than inherit its predecessor's final report, which is the
    // path that used to surface the terminal error.
    std::thread::sleep(Duration::from_millis(900));

    let client_b2 = cluster.client();
    let mut it_b2 = client_b2.distribute(&graph, coord_cfg("replace", 2, 1)).unwrap();
    // The replacement walks forward from round 0 over the 8 rounds its
    // predecessor fully consumed (each worker answers with a skip hint);
    // its first real delivery is round 8, so continuing the inherited
    // cursor keeps the tracker labels truthful.
    for _ in 0..6 {
        drain_slot(&mut it_a, &mut tracker, &mut a_rounds, 0, 1);
        drain_slot(&mut it_b2, &mut tracker, &mut b_rounds, 1, 1);
    }

    let skipped = client_b2.metrics().counter("client/rounds_skipped_forward").get();
    assert!(skipped >= 8, "replacement skipped {skipped} rounds, expected >= 8");
    assert_eq!(client_a.metrics().counter("client/rounds_skipped_forward").get(), 0);
    assert_eq!(client_b.metrics().counter("client/rounds_skipped_forward").get(), 0);
    let report = tracker.report();
    assert_eq!(report.duplicate_deliveries, 0, "{report:?}");
    assert_eq!(report.below_floor_deliveries, 0, "{report:?}");
    assert_eq!((a_rounds, b_rounds), (14, 14));
    it_a.release();
    it_b2.release();
}

/// Elastic membership e2e: a live coordinated job is resized 2 -> 3 -> 2.
/// The third slot activates at the grow barrier, consumes exactly once
/// per round while it exists, and drains to a clean end-of-stream at the
/// shrink barrier. No slot ever skips (skip-forward is reserved for the
/// replacement path) and no (round, slot) is delivered twice.
#[test]
fn elastic_width_change_grows_and_shrinks() {
    let cluster = Cluster::with_config(3, DispatcherConfig::default());
    let _ticker = start_ticker(&cluster, Duration::from_millis(50));
    let graph = PipelineBuilder::source_range(1_000_000).build();

    let client_a = cluster.client();
    let client_b = cluster.client();
    let mut it_a = client_a.distribute(&graph, coord_cfg("elastic", 2, 0)).unwrap();
    let mut it_b = client_b.distribute(&graph, coord_cfg("elastic", 2, 1)).unwrap();

    let mut tracker = RoundTracker::new();
    let (mut a_rounds, mut b_rounds) = (0u64, 0u64);
    for _ in 0..5 {
        drain_slot(&mut it_a, &mut tracker, &mut a_rounds, 0, 1);
        drain_slot(&mut it_b, &mut tracker, &mut b_rounds, 1, 1);
    }
    // Let progress heartbeats land so the grow barrier sits near the
    // consumption frontier (any barrier is correct; a fresh one keeps the
    // buffered-round window comfortably inside worker prefetch depth).
    std::thread::sleep(Duration::from_millis(300));

    let job_id = it_a.job_id();
    let (epoch1, b1) = cluster.dispatcher().set_job_consumers(job_id, 3).unwrap();
    assert_eq!(epoch1, 1);

    // Slot 2 joins mid-job and activates at the grow barrier.
    let client_c = cluster.client();
    let mut it_c = client_c.distribute(&graph, coord_cfg("elastic", 3, 2)).unwrap();
    let mut c_rounds = b1;
    for _ in 0..8 {
        drain_slot(&mut it_a, &mut tracker, &mut a_rounds, 0, 1);
        drain_slot(&mut it_b, &mut tracker, &mut b_rounds, 1, 1);
        drain_slot(&mut it_c, &mut tracker, &mut c_rounds, 2, 1);
    }
    wait_until(Instant::now() + Duration::from_secs(10), "width schedule delivery", || {
        cluster
            .with_worker(0, |w| w.metrics().counter("worker/width_updates_applied").get() >= 1)
            .unwrap_or(false)
    });

    // Shrink back to 2: the barrier must move strictly forward and slot 2
    // must drain the rounds it still owns, then end cleanly.
    std::thread::sleep(Duration::from_millis(300));
    let (epoch2, b2) = cluster.dispatcher().set_job_consumers(job_id, 2).unwrap();
    assert_eq!(epoch2, 2);
    assert!(b2 > b1, "shrink barrier {b2} must advance past grow barrier {b1}");

    let deadline = Instant::now() + Duration::from_secs(60);
    let mut c_done = false;
    while !c_done {
        assert!(Instant::now() < deadline, "slot 2 never drained to end-of-stream");
        drain_slot(&mut it_a, &mut tracker, &mut a_rounds, 0, 1);
        drain_slot(&mut it_b, &mut tracker, &mut b_rounds, 1, 1);
        match it_c.next().expect("shrunk slot must end cleanly, not error") {
            Some(e) => {
                assert!(!e.tensors.is_empty());
                tracker.observe(c_rounds, 2, 0);
                c_rounds += 1;
            }
            None => c_done = true,
        }
    }
    // The survivors keep flowing at the post-shrink width.
    for _ in 0..4 {
        drain_slot(&mut it_a, &mut tracker, &mut a_rounds, 0, 1);
        drain_slot(&mut it_b, &mut tracker, &mut b_rounds, 1, 1);
    }

    for c in [&client_a, &client_b, &client_c] {
        assert_eq!(c.metrics().counter("client/rounds_skipped_forward").get(), 0);
    }
    assert_eq!(cluster.dispatcher().metrics().counter("dispatcher/consumer_set_changes").get(), 2);
    let report = tracker.report();
    assert_eq!(report.duplicate_deliveries, 0, "{report:?}");
    assert_eq!(report.below_floor_deliveries, 0, "{report:?}");
    assert!(c_rounds > b1, "slot 2 delivered no rounds while it existed");
    it_a.release();
    it_b.release();
    it_c.release();
}

/// Slow-owner skew: one (seed-chosen) worker runs with a minimal round
/// prefetch depth, so its residue class materializes late every round.
/// Lockstep consumers must absorb the skew — no skips, no duplicate
/// slots, no stall — because rounds gate on the slowest owner by design.
#[test]
fn slow_owner_skew_preserves_round_invariants() {
    let slow = (fault_seed(42) % 3) as usize;
    let cluster = Cluster::with_config(0, DispatcherConfig::default());
    for i in 0..3 {
        cluster.set_worker_config(|c| c.round_prefetch_depth = if i == slow { 1 } else { 4 });
        cluster.add_worker();
    }
    let _ticker = start_ticker(&cluster, Duration::from_millis(50));
    let graph = PipelineBuilder::source_range(1_000_000).build();

    let client_a = cluster.client();
    let client_b = cluster.client();
    let mut it_a = client_a.distribute(&graph, coord_cfg("skew", 2, 0)).unwrap();
    let mut it_b = client_b.distribute(&graph, coord_cfg("skew", 2, 1)).unwrap();

    let mut tracker = RoundTracker::new();
    let (mut a_rounds, mut b_rounds) = (0u64, 0u64);
    let deadline = Instant::now() + Duration::from_secs(60);
    for _ in 0..30 {
        drain_slot(&mut it_a, &mut tracker, &mut a_rounds, 0, 1);
        drain_slot(&mut it_b, &mut tracker, &mut b_rounds, 1, 1);
        assert!(Instant::now() < deadline, "skewed round plane stalled");
    }

    assert_eq!(client_a.metrics().counter("client/rounds_skipped_forward").get(), 0);
    assert_eq!(client_b.metrics().counter("client/rounds_skipped_forward").get(), 0);
    let report = tracker.report();
    assert_eq!(report.duplicate_deliveries, 0, "{report:?}");
    assert_eq!(report.below_floor_deliveries, 0, "{report:?}");
    assert_eq!(report.rounds_seen as u64, 30);
    it_a.release();
    it_b.release();
}

/// Preemption wave (orchestrator failure injector over a [`Cell`]): a
/// coordinated job rides out a seeded storm of worker kills with delayed
/// replacements — every replacement is a brand-new identity, so this
/// exercises lease reassignment to late joiners rather than stable
/// -address revival. The round plane must keep flowing with every round
/// delivered exactly once and zero skips.
#[test]
fn preemption_wave_keeps_coordinated_rounds_exactly_once() {
    let store = ObjectStore::in_memory();
    let dcfg = DispatcherConfig {
        worker_timeout: Duration::from_millis(500),
        ..Default::default()
    };
    let cell = Arc::new(Cell::new(store, UdfRegistry::with_builtins(), dcfg).unwrap());
    cell.scale_to(4).unwrap();
    // Lease ticker for the stretches when the injector is not running.
    let stop_tick = Arc::new(AtomicBool::new(false));
    let ticker = {
        let (c, s) = (cell.clone(), stop_tick.clone());
        std::thread::spawn(move || {
            while !s.load(Ordering::SeqCst) {
                c.tick();
                std::thread::sleep(Duration::from_millis(50));
            }
        })
    };

    let graph = PipelineBuilder::source_range(1_000_000).build();
    let client = ServiceClient::new(&cell.dispatcher_addr());
    let mut it = client.distribute(&graph, coord_cfg("wave", 1, 0)).unwrap();
    let mut tracker = RoundTracker::new();
    let mut rounds = 0u64;
    drain_rounds(&mut it, &mut tracker, &mut rounds, 5);

    let inj = FailureInjector::start(
        cell.clone(),
        FailureConfig {
            kill_probability: 0.5,
            tick: Duration::from_millis(120),
            restart_after: Some(Duration::from_millis(150)),
            drain_notice: None,
            seed: fault_seed(17),
        },
    );
    // Ride the wave until both enough rounds flowed *and* the storm
    // actually struck at least twice (an unpaced drain could otherwise
    // outrun the injector's first tick). The per-round pause keeps the
    // wave several injector ticks long.
    let deadline = Instant::now() + Duration::from_secs(120);
    while rounds < 25 || inj.kills.load(Ordering::SeqCst) < 2 {
        drain_rounds(&mut it, &mut tracker, &mut rounds, 1);
        std::thread::sleep(Duration::from_millis(25));
        assert!(Instant::now() < deadline, "round plane stalled under the preemption wave");
    }
    // Let pending replacement restarts land before stopping the storm.
    std::thread::sleep(Duration::from_millis(400));
    inj.stop();
    assert!(inj.kills.load(Ordering::SeqCst) >= 2, "the wave never killed a worker");
    assert!(inj.restarts.load(Ordering::SeqCst) >= 1, "no replacement worker ever started");

    // Calm water: the (partly replaced) pool still serves rounds.
    drain_rounds(&mut it, &mut tracker, &mut rounds, 5);
    assert_eq!(client.metrics().counter("client/rounds_skipped_forward").get(), 0);
    let report = tracker.report();
    assert_eq!(report.duplicate_deliveries, 0, "{report:?}");
    assert_eq!(report.below_floor_deliveries, 0, "{report:?}");
    assert!(rounds >= 30, "expected at least 30 rounds, saw {rounds}");
    it.release();
    stop_tick.store(true, Ordering::SeqCst);
    let _ = ticker.join();
}

/// Graceful scale-down mid-coordinated-epoch: a worker holding round
/// leases is drained via the two-phase revoke-ack-grant handoff while a
/// consumer keeps stepping. The drain must complete (worker removed,
/// `dispatcher/workers_drained` counted, handoffs completed), every
/// round must still be delivered exactly once with zero skips, and no
/// client step may stall longer than ~one heartbeat — the draining
/// owner keeps serving its residues until the instant the gainer owns
/// them.
#[test]
fn graceful_scale_down_mid_epoch_is_exactly_once_and_stall_free() {
    let store = ObjectStore::in_memory();
    let dcfg = DispatcherConfig {
        worker_timeout: Duration::from_millis(800),
        ..Default::default()
    };
    let cell = Arc::new(Cell::new(store, UdfRegistry::with_builtins(), dcfg).unwrap());
    cell.scale_to(4).unwrap();
    // Drive the drain state machine like the scaling controller does:
    // tick plans handoffs, reap removes workers whose drain completed.
    let stop_tick = Arc::new(AtomicBool::new(false));
    let ticker = {
        let (c, s) = (cell.clone(), stop_tick.clone());
        std::thread::spawn(move || {
            while !s.load(Ordering::SeqCst) {
                c.tick();
                c.reap_drained();
                std::thread::sleep(Duration::from_millis(50));
            }
        })
    };

    let graph = PipelineBuilder::source_range(1_000_000).build();
    let client = ServiceClient::new(&cell.dispatcher_addr());
    let mut it = client.distribute(&graph, coord_cfg("drain", 1, 0)).unwrap();
    let mut tracker = RoundTracker::new();
    let mut rounds = 0u64;
    drain_rounds(&mut it, &mut tracker, &mut rounds, 8);

    // Begin the graceful drain of one (least-loaded) worker and keep
    // stepping right through it, timing every step.
    let drained_counter = cell.dispatcher().metrics().counter("dispatcher/workers_drained");
    cell.request_scale_to(3).unwrap();
    let mut max_gap = Duration::ZERO;
    let deadline = Instant::now() + Duration::from_secs(30);
    while drained_counter.get() < 1 || rounds < 40 {
        let t0 = Instant::now();
        drain_rounds(&mut it, &mut tracker, &mut rounds, 1);
        max_gap = max_gap.max(t0.elapsed());
        assert!(Instant::now() < deadline, "drain never completed while rounds flowed");
    }

    // The drain was graceful and complete: worker gone, leases handed
    // off through the two-phase path, nothing force-killed.
    assert_eq!(cell.worker_count(), 3);
    let m = cell.dispatcher().metrics();
    assert!(m.counter("dispatcher/worker_drains_started").get() >= 1);
    assert_eq!(drained_counter.get(), 1, "exactly the requested worker drained");
    assert!(
        m.counter("dispatcher/lease_handoffs_completed").get() >= 1,
        "the draining owner's residue moved via revoke-ack-grant"
    );
    // Stall bound: the §3.6 contract is that the loser serves until the
    // gainer's grant activates, so a step never waits out a lease the
    // way a crash does. One worker heartbeat (100 ms) is the protocol
    // bound; 5x covers CI scheduler noise.
    assert!(
        max_gap < Duration::from_millis(500),
        "a step stalled {max_gap:?} during the graceful drain"
    );

    // Calm water: the shrunken pool keeps serving.
    drain_rounds(&mut it, &mut tracker, &mut rounds, 5);
    assert_eq!(client.metrics().counter("client/rounds_skipped_forward").get(), 0);
    let report = tracker.report();
    assert_eq!(report.duplicate_deliveries, 0, "{report:?}");
    assert_eq!(report.below_floor_deliveries, 0, "{report:?}");
    it.release();
    stop_tick.store(true, Ordering::SeqCst);
    let _ = ticker.join();
}

/// Preemption *with advance notice* (`DrainNotice`): the injector begins
/// a graceful drain, waits out the notice, then kills regardless — a
/// drain that finished in time makes the kill a no-op. Versus the plain
/// -kill wave above, the round plane sees strictly gentler faults, and
/// the same exactly-once/zero-skip invariants must hold.
#[test]
fn preemption_with_drain_notice_keeps_rounds_exactly_once() {
    let store = ObjectStore::in_memory();
    let dcfg = DispatcherConfig {
        worker_timeout: Duration::from_millis(500),
        ..Default::default()
    };
    let cell = Arc::new(Cell::new(store, UdfRegistry::with_builtins(), dcfg).unwrap());
    cell.scale_to(4).unwrap();
    let stop_tick = Arc::new(AtomicBool::new(false));
    let ticker = {
        let (c, s) = (cell.clone(), stop_tick.clone());
        std::thread::spawn(move || {
            while !s.load(Ordering::SeqCst) {
                c.tick();
                c.reap_drained();
                std::thread::sleep(Duration::from_millis(50));
            }
        })
    };

    let graph = PipelineBuilder::source_range(1_000_000).build();
    let client = ServiceClient::new(&cell.dispatcher_addr());
    let mut it = client.distribute(&graph, coord_cfg("notice", 1, 0)).unwrap();
    let mut tracker = RoundTracker::new();
    let mut rounds = 0u64;
    drain_rounds(&mut it, &mut tracker, &mut rounds, 5);

    let inj = FailureInjector::start(
        cell.clone(),
        FailureConfig {
            kill_probability: 0.5,
            tick: Duration::from_millis(120),
            restart_after: Some(Duration::from_millis(150)),
            // ~3 worker heartbeats of warning: enough for a quiet worker
            // to hand its leases off before the axe falls.
            drain_notice: Some(Duration::from_millis(350)),
            seed: fault_seed(23),
        },
    );
    let deadline = Instant::now() + Duration::from_secs(120);
    while rounds < 25 || inj.drains.load(Ordering::SeqCst) < 2 {
        drain_rounds(&mut it, &mut tracker, &mut rounds, 1);
        std::thread::sleep(Duration::from_millis(25));
        assert!(Instant::now() < deadline, "round plane stalled under noticed preemptions");
    }
    std::thread::sleep(Duration::from_millis(400));
    inj.stop();
    assert!(inj.drains.load(Ordering::SeqCst) >= 2, "no advance notice was ever delivered");
    assert!(inj.kills.load(Ordering::SeqCst) >= 2, "deferred kills never fired");
    assert!(
        cell.dispatcher().metrics().counter("dispatcher/worker_drains_started").get() >= 2,
        "notices did not reach the dispatcher's drain state machine"
    );

    drain_rounds(&mut it, &mut tracker, &mut rounds, 5);
    assert_eq!(client.metrics().counter("client/rounds_skipped_forward").get(), 0);
    let report = tracker.report();
    assert_eq!(report.duplicate_deliveries, 0, "{report:?}");
    assert_eq!(report.below_floor_deliveries, 0, "{report:?}");
    assert!(rounds >= 30, "expected at least 30 rounds, saw {rounds}");
    it.release();
    stop_tick.store(true, Ordering::SeqCst);
    let _ = ticker.join();
}

/// Shared-job client config for the spill-tier tests: anonymous
/// independent job with ephemeral sharing enabled.
fn share_cfg() -> ServiceClientConfig {
    ServiceClientConfig {
        sharding: ShardingPolicy::Off,
        sharing: SharingMode::Auto,
        ..Default::default()
    }
}

/// Drain an independent-mode iterator to end-of-stream, collecting ids.
fn drain_ids(it: &mut DistributedIter, ids: &mut Vec<u64>) {
    while let Some(e) = it.next().expect("element fetch failed") {
        ids.extend(e.ids);
    }
}

/// Spill-tier crash e2e: a worker dies mid-epoch with part of the stream
/// already tiered to the object store. Its replacement (same advertised
/// address, same shared store) must adopt the predecessor's committed
/// manifest and serve that prefix straight from the store — a client
/// attaching *after* the crash replays the full epoch exactly once with
/// zero relaxed-visitation skips, and the surviving client loses
/// nothing (its re-handshake replays, so it sees every id at least
/// once).
#[test]
fn worker_crash_mid_spill_replacement_serves_committed_prefix() {
    let cluster = Cluster::with_config(0, DispatcherConfig::default());
    cluster.set_worker_config(|c| {
        // Small segments so the committed prefix spans many objects;
        // eager eviction (the default) tiers every consumed element out
        // of the 16-element RAM window into the store.
        c.spill = SpillConfig { policy: SpillPolicy::All, segment_bytes: 512 };
    });
    cluster.add_worker();
    let _ticker = start_ticker(&cluster, Duration::from_millis(50));

    // ~1 ms of preprocessing per element keeps the epoch in flight long
    // enough that the kill usually lands mid-spill (the test is still
    // correct if production finishes first: the adopted manifest is
    // simply complete).
    let total = 400u64;
    let graph = PipelineBuilder::source_range(total).map("synthetic.burn:1000").build();

    let client_a = cluster.client();
    let mut it_a = client_a.distribute(&graph, share_cfg()).unwrap();
    let mut ids_a: Vec<u64> = Vec::new();
    while ids_a.len() < 60 {
        let e = it_a.next().expect("element fetch failed").expect("stream ended early");
        ids_a.extend(e.ids);
    }
    wait_until(Instant::now() + Duration::from_secs(10), "first spill segment", || {
        cluster
            .with_worker(0, |w| w.metrics().counter("worker/spill_segments_written").get() >= 1)
            .unwrap_or(false)
    });

    // Crash: heartbeats stop, the data server dies, the pending spill
    // buffer is lost. The manifest in the store is the committed prefix.
    cluster.kill_worker(0);
    cluster.revive_worker(0);

    // Pump the survivor well past the RAM window so the replacement's
    // window base has provably moved off zero by the time the attacher
    // joins (its session re-anchored at the spill floor, so these pulls
    // start by replaying the committed prefix).
    while ids_a.len() < 160 {
        let e = it_a.next().expect("element fetch failed").expect("stream ended early");
        ids_a.extend(e.ids);
    }

    // A second trainer submits the identical pipeline after the crash
    // and attaches to the live job. The replacement worker adopted the
    // predecessor's manifest, so the attacher anchors at sequence 0 and
    // replays the committed prefix from the store (RAM only holds the
    // newest window).
    let client_c = cluster.client();
    let mut it_c = client_c.distribute(&graph, share_cfg()).unwrap();
    assert!(it_c.attached(), "identical pipeline must attach to the live job");
    assert_eq!(it_c.job_id(), it_a.job_id());

    let mut ids_c: Vec<u64> = Vec::new();
    drain_ids(&mut it_c, &mut ids_c);
    ids_c.sort_unstable();
    assert_eq!(
        ids_c,
        (0..total).collect::<Vec<u64>>(),
        "post-crash attacher replays the full epoch exactly once"
    );

    // The survivor's session re-handshake re-anchors at the spill floor,
    // so it sees duplicates but never loses an element.
    drain_ids(&mut it_a, &mut ids_a);
    ids_a.sort_unstable();
    ids_a.dedup();
    assert_eq!(
        ids_a,
        (0..total).collect::<Vec<u64>>(),
        "surviving client covers the full epoch across the crash"
    );

    // The committed prefix really came from the store, and nobody was
    // forced to skip: the spill tier replaces relaxed visitation.
    cluster
        .with_worker(0, |w| {
            assert!(
                w.metrics().counter("worker/spill_elements_served").get() >= 1,
                "replacement never served from the adopted spill prefix"
            );
            assert_eq!(w.metrics().counter("worker/relaxed_visitation_skips").get(), 0);
        })
        .expect("replacement worker is up");
    it_a.release();
    it_c.release();
}

/// Fingerprint-keyed snapshot e2e: a spill-everything job completes its
/// epoch, the worker's complete manifest is journaled by the dispatcher
/// (`SnapshotCommitted`), and a *re-submitted identical pipeline* is
/// served straight out of the store — the worker streams the committed
/// segments instead of re-running the pipeline, so `elements_produced`
/// does not move for the second job.
#[test]
fn completed_epoch_commits_snapshot_and_resubmission_streams_from_store() {
    let cluster = Cluster::with_config(0, DispatcherConfig::default());
    cluster.set_worker_config(|c| {
        c.spill = SpillConfig { policy: SpillPolicy::All, segment_bytes: 512 };
    });
    cluster.add_worker();
    let _ticker = start_ticker(&cluster, Duration::from_millis(50));

    let total = 300u64;
    let graph = PipelineBuilder::source_range(total).build();

    // First epoch: live production with the spill tier archiving the
    // whole stream.
    let client_a = cluster.client();
    let mut it_a = client_a.distribute(&graph, share_cfg()).unwrap();
    assert!(!it_a.snapshot(), "no snapshot exists yet: first job must produce live");
    let mut ids_a: Vec<u64> = Vec::new();
    drain_ids(&mut it_a, &mut ids_a);
    ids_a.sort_unstable();
    assert_eq!(ids_a, (0..total).collect::<Vec<u64>>(), "first epoch exactly once");

    // The worker finalizes the manifest at end-of-stream and re-reports
    // it every heartbeat until the dispatcher journals the commit.
    wait_until(Instant::now() + Duration::from_secs(10), "snapshot commit", || {
        cluster.dispatcher().metrics().counter("dispatcher/snapshots_committed").get() >= 1
    });
    it_a.release();

    let produced_before = cluster
        .with_worker(0, |w| w.metrics().counter("worker/elements_produced").get())
        .expect("worker is up");

    // Re-submission: same fingerprint, sharing auto, no live job left —
    // the dispatcher creates the job in snapshot-serve mode.
    let client_b = cluster.client();
    let mut it_b = client_b.distribute(&graph, share_cfg()).unwrap();
    assert!(it_b.snapshot(), "re-submitted pipeline must attach to the snapshot");
    assert!(!it_b.attached(), "snapshot serve is a fresh job, not a live attach");
    let mut ids_b: Vec<u64> = Vec::new();
    drain_ids(&mut it_b, &mut ids_b);
    ids_b.sort_unstable();
    assert_eq!(
        ids_b,
        (0..total).collect::<Vec<u64>>(),
        "snapshot-served epoch is byte-identical to the live one"
    );

    cluster
        .with_worker(0, |w| {
            assert!(
                w.metrics().counter("worker/snapshot_serves").get() >= 1,
                "worker never started a snapshot-serve task"
            );
            assert_eq!(
                w.metrics().counter("worker/elements_produced").get(),
                produced_before,
                "snapshot serve must not re-run the pipeline"
            );
            assert!(w.metrics().counter("worker/spill_segments_written").get() >= 1);
            assert_eq!(w.metrics().counter("worker/relaxed_visitation_skips").get(), 0);
        })
        .expect("worker is up");
    assert_eq!(client_b.metrics().counter("client/snapshot_attaches").get(), 1);
    assert_eq!(cluster.dispatcher().metrics().counter("dispatcher/snapshot_attaches").get(), 1);
    it_b.release();

    // Third phase — superseded-snapshot GC: a new *live* production of
    // the same fingerprint (sharing off never attaches) commits a newer
    // epoch; the dispatcher journals the hand-over and deletes the
    // replaced job's spill objects from the store.
    let old_job = it_a.job_id();
    assert!(cluster.store.contains(&data_key(old_job)), "first epoch's spill data present");
    let client_c = cluster.client();
    let mut cfg_c = share_cfg();
    cfg_c.sharing = SharingMode::Off;
    let mut it_c = client_c.distribute(&graph, cfg_c).unwrap();
    let mut ids_c: Vec<u64> = Vec::new();
    drain_ids(&mut it_c, &mut ids_c);
    assert_eq!(ids_c.len() as u64, total, "superseding epoch produced live");
    wait_until(Instant::now() + Duration::from_secs(10), "superseded spill GC", || {
        cluster.dispatcher().metrics().counter("dispatcher/spill_snapshots_gced").get() >= 1
    });
    assert!(!cluster.store.contains(&data_key(old_job)), "replaced spill data deleted");
    assert!(!cluster.store.contains(&manifest_key(old_job)), "replaced spill manifest deleted");
    assert!(cluster.store.contains(&data_key(it_c.job_id())), "superseding snapshot kept");
    it_c.release();
}

/// Satellite regression for the engine-poll removal: an idle concurrent
/// round engine must sleep on the demand condvar, not a timer. Over a
/// 1.5 s idle window (well inside the 5 s liveness watchdog) the
/// `client/round_engine_timer_wakeups` counter must not move, and the
/// engine must still deliver promptly when demand resumes.
#[test]
fn idle_round_engine_takes_no_timer_wakeups() {
    let cluster = Cluster::start(2);
    let _ticker = start_ticker(&cluster, Duration::from_millis(50));
    let graph = PipelineBuilder::source_range(1_000_000).build();
    let client = cluster.client();
    // Default config: stream sessions + concurrent round fetch.
    let mut it = client.distribute(&graph, coord_cfg("idle", 1, 0)).unwrap();

    let mut tracker = RoundTracker::new();
    let mut rounds = 0u64;
    drain_rounds(&mut it, &mut tracker, &mut rounds, 3);
    // Give in-flight prefetch lanes a beat to park before sampling.
    std::thread::sleep(Duration::from_millis(200));

    let wakeups = || client.metrics().counter("client/round_engine_timer_wakeups").get();
    let before = wakeups();
    std::thread::sleep(Duration::from_millis(1500));
    assert_eq!(wakeups() - before, 0, "idle engine woke from the watchdog timer");

    drain_rounds(&mut it, &mut tracker, &mut rounds, 3);
    let report = tracker.report();
    assert_eq!(report.duplicate_deliveries, 0, "{report:?}");
    assert_eq!(rounds, 6);
    it.release();
}

/// Acceptance: checkpoint compaction bounds restart replay cost. After a
/// long job-churn history is folded into a snapshot, a restart replays
/// only the (near-empty) suffix instead of the whole history; a stale
/// snapshot temp file from a crash mid-install is swept; and the
/// restored dispatcher still routes the live coordinated job.
#[test]
fn journal_compaction_bounds_restart_replay() {
    let jpath = journal_path("compact-replay");
    let dcfg = DispatcherConfig {
        worker_timeout: Duration::from_millis(800),
        journal_path: Some(jpath.clone()),
        ..Default::default()
    };
    let cluster = Cluster::with_config(1, dcfg);
    let _ticker = start_ticker(&cluster, Duration::from_millis(50));

    // A live coordinated job that must stay routable across the restart.
    let graph = PipelineBuilder::source_range(100_000).build();
    let client = cluster.client();
    let mut it = client.distribute(&graph, coord_cfg("compact-live", 1, 0)).unwrap();
    let mut tracker = RoundTracker::new();
    let mut rounds = 0u64;
    drain_rounds(&mut it, &mut tracker, &mut rounds, 4);

    // Churn history: short-lived anonymous jobs, several records each.
    let churn = cluster.client();
    for i in 0..40u64 {
        let g = PipelineBuilder::source_range(10 + i).build();
        let mut j = churn.distribute(&g, ServiceClientConfig::default()).unwrap();
        j.release();
    }
    let history = Journal::replay(&jpath).unwrap().len();
    assert!(history >= 100, "churn built a real history ({history} records)");

    // Checkpoint, then fake a crash mid-*next*-install: the temp file
    // must be invisible to restore and swept on reopen.
    assert_eq!(cluster.dispatcher().compact_now(), Some(1));
    let tmp = jpath.with_file_name(format!(
        "{}.snap-2.tmp",
        jpath.file_name().unwrap().to_str().unwrap()
    ));
    std::fs::write(&tmp, b"torn half-written snapshot").unwrap();

    cluster.restart_dispatcher(Duration::from_millis(200));
    let d = cluster.dispatcher();
    let replayed = d.metrics().counter("dispatcher/restore_records_replayed").get();
    assert!(
        replayed * 10 <= history as u64,
        "restart replayed {replayed} records against a {history}-record history"
    );
    assert_eq!(d.metrics().counter("dispatcher/restore_fallbacks").get(), 0);
    wait_until(Instant::now() + Duration::from_secs(5), "tmp snapshot sweep", || {
        !tmp.exists()
    });

    // The live job replays out of the snapshot and keeps serving.
    tracker.set_floor(rounds);
    drain_rounds(&mut it, &mut tracker, &mut rounds, 4);
    let report = tracker.report();
    assert_eq!(report.duplicate_deliveries, 0, "{report:?}");
    assert_eq!(report.below_floor_deliveries, 0, "{report:?}");
    it.release();
}

/// Acceptance: a CRC-corrupted newest snapshot does not take the control
/// plane down — restore falls back (here: to full genesis replay, which
/// retention guarantees is still possible one step back) and live jobs
/// stay routable.
#[test]
fn corrupted_newest_snapshot_falls_back_and_keeps_jobs_routable() {
    let jpath = journal_path("corrupt-snap");
    let dcfg = DispatcherConfig {
        worker_timeout: Duration::from_millis(800),
        journal_path: Some(jpath.clone()),
        ..Default::default()
    };
    let cluster = Cluster::with_config(1, dcfg);
    let _ticker = start_ticker(&cluster, Duration::from_millis(50));

    let graph = PipelineBuilder::source_range(100_000).build();
    let client = cluster.client();
    let mut it = client.distribute(&graph, coord_cfg("corrupt-live", 1, 0)).unwrap();
    let mut tracker = RoundTracker::new();
    let mut rounds = 0u64;
    drain_rounds(&mut it, &mut tracker, &mut rounds, 4);

    assert_eq!(cluster.dispatcher().compact_now(), Some(1));
    // Flip one snapshot body byte: the frame CRC rejects the whole file.
    let snap = jpath.with_file_name(format!(
        "{}.snap-1",
        jpath.file_name().unwrap().to_str().unwrap()
    ));
    let mut bytes = std::fs::read(&snap).unwrap();
    assert!(bytes.len() > 8, "snapshot has a body");
    bytes[8] ^= 0xff;
    std::fs::write(&snap, &bytes).unwrap();

    cluster.restart_dispatcher(Duration::from_millis(200));
    let d = cluster.dispatcher();
    assert!(
        d.metrics().counter("dispatcher/restore_fallbacks").get() >= 1,
        "corrupt snapshot must be counted as a fallback"
    );
    assert!(
        d.metrics().counter("dispatcher/restore_records_replayed").get() >= 1,
        "fallback restore replays the journal instead"
    );

    // Degraded recovery freshness, full availability: the job replays
    // from genesis and keeps serving rounds exactly once.
    tracker.set_floor(rounds);
    drain_rounds(&mut it, &mut tracker, &mut rounds, 6);
    let report = tracker.report();
    assert_eq!(report.duplicate_deliveries, 0, "{report:?}");
    assert_eq!(report.below_floor_deliveries, 0, "{report:?}");
    it.release();
}

/// Seeded differential battery for the slice-by-16 CRC against the
/// byte-at-a-time scalar oracle: random buffers, random streaming split
/// points, and misaligned sub-slices must agree bit-for-bit. The CI seed
/// matrix (`TFDATASVC_FAULT_SEED`) varies the buffer population, so each
/// hygiene run exercises a different corner of the 16-lane fold.
#[test]
fn crc32_slice16_matches_scalar_oracle_on_seeded_buffers() {
    let seed = fault_seed(20260728);
    let mut rng = Rng::new(0xC12C ^ seed);
    for round in 0..200u32 {
        let len = rng.below(8192) as usize;
        let mut buf = vec![0u8; len];
        for b in buf.iter_mut() {
            *b = rng.next_u32() as u8;
        }
        let want = crc32_scalar(&buf);
        assert_eq!(crc32(&buf), want, "one-shot mismatch (round {round}, len {len})");
        // Streaming over random split points must match the one-shot
        // digest no matter how the 16-byte main loop gets sliced up.
        let mut h = Hasher::new();
        let mut off = 0;
        while off < len {
            let take = (rng.below(64) as usize + 1).min(len - off);
            h.update(&buf[off..off + take]);
            off += take;
        }
        assert_eq!(h.finalize(), want, "streaming mismatch (round {round}, len {len})");
        // Misaligned view: the accelerated path may not assume any
        // particular start alignment for the slice it is handed.
        if len > 4 {
            let skip = rng.below(3) as usize + 1;
            assert_eq!(
                crc32(&buf[skip..]),
                crc32_scalar(&buf[skip..]),
                "misaligned mismatch (round {round}, len {len}, skip {skip})"
            );
        }
    }
}

/// Seeded adaptive-codec decision property: interleaved compressible
/// (zero-heavy) and incompressible (random-byte) frame classes through
/// one codec must settle to per-class verdicts — LZ for the former, Skip
/// for the latter — and every frame the codec does compress must
/// round-trip losslessly through the wire codec. Mirrors exactly what
/// `assemble_batch_frame` does with the planner's verdicts.
#[test]
fn adaptive_codec_settles_per_class_under_seeded_interleaving() {
    let seed = fault_seed(20260728);
    let mut rng = Rng::new(0xC0DE ^ seed);
    let codec = AdaptiveCodec::new();
    let (mut lz_frames, mut skip_plans) = (0u64, 0u64);
    for _ in 0..256 {
        let incompressible = rng.chance(0.5);
        let frame = if incompressible {
            // Random bytes, 16-32 KiB: LZ cannot reach the worthwhile bar.
            let len = 16_384 + rng.below(16_384) as usize;
            let mut v = vec![0u8; len];
            for b in v.iter_mut() {
                *b = rng.next_u32() as u8;
            }
            v
        } else {
            // Zero-heavy rows, 1-2 KiB (a different size class): LZ wins.
            let len = 1024 + rng.below(1024) as usize;
            let mut v = vec![0u8; len];
            for b in v.iter_mut().step_by(37) {
                *b = rng.next_u32() as u8;
            }
            v
        };
        match codec.plan(frame.len()) {
            CodecAction::Trial => {
                let z = compress(&frame);
                codec.record_trial(frame.len(), z.len());
                assert_eq!(decompress(&z).unwrap(), frame, "trial frame must round-trip");
            }
            CodecAction::Compress => {
                assert!(!incompressible, "random frames must never settle on LZ");
                let z = compress(&frame);
                assert!(z.len() < frame.len(), "settled LZ class stopped compressing");
                assert_eq!(decompress(&z).unwrap(), frame, "settled frame must round-trip");
                lz_frames += 1;
            }
            CodecAction::Skip => {
                assert!(incompressible, "zero-heavy frames must never settle on Skip");
                skip_plans += 1;
            }
        }
    }
    assert!(lz_frames > 0, "compressible class never settled on Compress");
    assert!(skip_plans > 0, "incompressible class never settled on Skip");
    assert_eq!(codec.decision_for_len(20_000), Some(false), "16-32 KiB class verdict");
    assert_eq!(codec.decision_for_len(1500), Some(true), "1-2 KiB class verdict");
}

/// Concurrent shared-fetch e2e over the public client API: k anonymous
/// clients attach to one structurally-fingerprinted job (join all, then
/// drain concurrently) against a single deep-windowed worker with eager
/// eviction off, so no cursor can ever fall off the sliding window.
/// Sharing must then be exactly-once per client — every client sees the
/// complete id stream in order, with zero relaxed-visitation skips —
/// while the pool produces the epoch exactly once (§3.5's sharded
/// sliding cache serving k cursors from one production run).
#[test]
fn concurrent_shared_fetch_is_exactly_once_per_client() {
    let cluster = Cluster::with_config(0, DispatcherConfig::default());
    cluster.set_worker_config(|c| {
        c.cache_window = 1 << 16;
        c.cache_window_bytes = 256 << 20;
        c.eager_window_eviction = false;
    });
    cluster.add_worker();

    let total = 1024u64;
    let graph = PipelineBuilder::source_range(total).batch(8).build();
    let k = 4;
    // Join all k clients first, so every attach targets the live job…
    let iters: Vec<DistributedIter> = (0..k)
        .map(|_| cluster.client().distribute(&graph, share_cfg()).unwrap())
        .collect();
    // …then drain concurrently from real threads.
    let handles: Vec<_> = iters
        .into_iter()
        .map(|mut it| {
            std::thread::spawn(move || {
                let mut ids = Vec::new();
                drain_ids(&mut it, &mut ids);
                (ids, it.job_id(), it.attached())
            })
        })
        .collect();
    let results: Vec<(Vec<u64>, u64, bool)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    let mut jobs: Vec<u64> = results.iter().map(|r| r.1).collect();
    jobs.sort_unstable();
    jobs.dedup();
    assert_eq!(jobs.len(), 1, "all clients must share one fingerprinted job");
    assert_eq!(
        results.iter().filter(|r| r.2).count(),
        k - 1,
        "every client after the first must attach to the existing job"
    );
    let want: Vec<u64> = (0..total).collect();
    for (i, (ids, _, _)) in results.iter().enumerate() {
        assert_eq!(
            ids, &want,
            "client {i} must see the whole epoch in order, exactly once"
        );
    }
    let produced = cluster
        .with_worker(0, |w| w.metrics().counter("worker/elements_produced").get())
        .unwrap();
    assert_eq!(produced, total / 8, "the shared epoch is produced exactly once");
    let skips = cluster
        .with_worker(0, |w| w.metrics().counter("worker/relaxed_visitation_skips").get())
        .unwrap();
    assert_eq!(skips, 0, "nothing evicted under a deep window, so nothing skipped");
}
