"""L1 Pallas kernels for the service's compute hot-spots.

- augment: fused image augmentation (worker-side vision preprocessing).
- ffn: fused transformer FFN block (client-side train step).
- ref: pure-jnp oracles for both (correctness ground truth).
"""

from . import augment, ffn, ref  # noqa: F401
