//! Fig. 2: RetinaNet/COCO colocated CPU & memory usage over time.
//!
//! Paper: colocated preprocessing makes host CPU bursty (near-saturated
//! while preparing batches, near-idle during the accelerator step),
//! which is why spare host resources cannot safely be loaned out. We
//! regenerate the timeline from the colocated step cycle and report the
//! burstiness statistics the argument rests on.

use tfdatasvc::metrics::write_csv_rows;
use tfdatasvc::sim::fleet::burstiness_timeline;
use tfdatasvc::util::hist::Samples;

fn main() {
    // RetinaNet-like: ~2 s steps, ~40% of each step preprocessing-heavy.
    let tl = burstiness_timeline(600.0, 2.0, 0.4, 0x0f16_0002);
    let mut cpu = Samples::from_vec(tl.iter().map(|p| p.cpu).collect());
    let mut mem = Samples::from_vec(tl.iter().map(|p| p.mem).collect());

    println!("=== Fig 2: colocated CPU/MEM usage timeline (600 s) ===");
    println!(
        "CPU: mean {:.2}  p5 {:.2}  p95 {:.2}  (bursty: p95/p5 = {:.1}x)",
        cpu.mean(),
        cpu.percentile(5.0),
        cpu.percentile(95.0),
        cpu.percentile(95.0) / cpu.percentile(5.0).max(1e-9)
    );
    println!("MEM: mean {:.2}  p95 {:.2}  (stable)", mem.mean(), mem.percentile(95.0));

    let rows: Vec<Vec<String>> = tl
        .iter()
        .step_by(5)
        .map(|p| vec![format!("{:.2}", p.t), format!("{:.3}", p.cpu), format!("{:.3}", p.mem)])
        .collect();
    write_csv_rows("out/fig2_timeline.csv", "t_s,cpu_util,mem_util", &rows).unwrap();

    // The colocation argument: mean is moderate but the p95/p5 swing is
    // huge, so a colocated tenant would face constant interference.
    assert!(cpu.mean() < 0.6, "mean CPU looks loanable...");
    assert!(cpu.percentile(95.0) / cpu.percentile(5.0).max(1e-9) > 4.0, "...but bursts forbid it");
    println!("fig2 OK -> out/fig2_timeline.csv");
}
