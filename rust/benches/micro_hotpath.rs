//! Hot-path microbenchmarks (§Perf in EXPERIMENTS.md).
//!
//! Measures the real components on this machine:
//!   * wire encode/decode of a batch-sized Element,
//!   * RPC round-trip latency and streaming throughput (loopback),
//!   * pipeline executor throughput (map / parallel map / batch),
//!   * sliding-window cache serve rate,
//!   * end-to-end service GetElement throughput,
//!   * PJRT preprocess + train-step latency (if artifacts exist).

use std::sync::Arc;
use std::time::{Duration, Instant};
use tfdatasvc::data::element::{Element, Tensor};
use tfdatasvc::data::exec::{ElemIter, Executor, ExecutorConfig};
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::orchestrator::Cell;
use tfdatasvc::rpc::{Client, Server};
use tfdatasvc::service::dispatcher::DispatcherConfig;
use tfdatasvc::service::proto::ShardingPolicy;
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::storage::dataset::{generate_vision, VisionGenConfig};
use tfdatasvc::storage::ObjectStore;
use tfdatasvc::wire::{Decode, Encode};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    for _ in 0..iters.div_ceil(10) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<44} {:>10.1} µs/op {:>12.0} op/s", per * 1e6, 1.0 / per);
    per
}

fn batch_element() -> Element {
    // A 16x32x32x3 f32 batch + labels: ~196 KiB, typical demo batch.
    Element::with_ids(
        vec![
            Tensor::from_f32(vec![16, 32, 32, 3], &vec![0.5; 16 * 32 * 32 * 3]),
            Tensor::from_u32(vec![16], &[7; 16]),
        ],
        (0..16).collect(),
    )
}

fn main() {
    println!("=== micro_hotpath ===");

    // ---- wire ----
    let elem = batch_element();
    let bytes = elem.to_bytes();
    println!("element size on wire: {} KiB", bytes.len() / 1024);
    bench("wire: encode batch element", 2000, || {
        std::hint::black_box(elem.to_bytes());
    });
    bench("wire: decode batch element", 2000, || {
        std::hint::black_box(Element::from_bytes(&bytes).unwrap());
    });

    // ---- rpc ----
    let srv = Server::bind("127.0.0.1:0", |_m, p: &[u8]| Ok(p.to_vec().into())).unwrap();
    let client = Client::connect(&srv.local_addr().to_string(), Duration::from_secs(2)).unwrap();
    bench("rpc: 64 B round-trip (loopback)", 2000, || {
        client.call(1, b"ping64bytes_ping64bytes_ping64bytes_ping64bytes_ping64.", Duration::from_secs(2)).unwrap();
    });
    let payload = vec![0u8; 1 << 20];
    let per = bench("rpc: 1 MiB echo (loopback)", 300, || {
        client.call(1, &payload, Duration::from_secs(5)).unwrap();
    });
    println!("{:<44} {:>10.2} Gbit/s", "rpc: implied loopback throughput", 2.0 * 8.0 / (per * 1e9) * 1e6 * (payload.len() as f64 / 1e6));

    // ---- pipeline executor ----
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "bench",
        &VisionGenConfig { num_shards: 4, samples_per_shard: 64, ..Default::default() },
    );
    let n_shards = spec.num_shards();
    let mk_exec = || {
        Executor::new(ExecutorConfig::local(store.clone(), UdfRegistry::with_builtins(), n_shards))
    };
    for (name, graph) in [
        ("pipeline: source+batch(16)", PipelineBuilder::source_vision(spec.clone()).batch(16).build()),
        (
            "pipeline: +normalize+augment map x1",
            PipelineBuilder::source_vision(spec.clone())
                .map("vision.normalize+vision.augment")
                .batch(16)
                .build(),
        ),
        (
            "pipeline: +normalize+augment pmap x8",
            PipelineBuilder::source_vision(spec.clone())
                .map_parallel("vision.normalize+vision.augment", 8)
                .batch(16)
                .build(),
        ),
    ] {
        let ex = mk_exec();
        let t0 = Instant::now();
        let mut total = 0usize;
        const REPS: usize = 8;
        for _ in 0..REPS {
            let mut it = ex.iterate(&graph).unwrap();
            while let Ok(Some(e)) = it.next() {
                total += e.ids.len();
            }
        }
        let eps = total as f64 / t0.elapsed().as_secs_f64();
        println!("{name:<44} {eps:>10.0} samples/s");
    }

    // ---- end-to-end service GetElement ----
    let cell = Arc::new(
        Cell::new(store.clone(), UdfRegistry::with_builtins(), DispatcherConfig::default()).unwrap(),
    );
    cell.scale_to(2).unwrap();
    let graph = PipelineBuilder::source_vision(spec).repeat(0).batch(16).take(200).build();
    let svc = ServiceClient::new(&cell.dispatcher_addr());
    let mut it = svc
        .distribute(&graph, ServiceClientConfig { sharding: ShardingPolicy::Off, ..Default::default() })
        .unwrap();
    let t0 = Instant::now();
    let mut batches = 0;
    let mut bytes_total = 0usize;
    while let Ok(Some(e)) = it.next() {
        batches += 1;
        bytes_total += e.byte_len();
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{:<44} {:>10.0} batches/s {:>8.0} MiB/s",
        "service: e2e GetElement (2 workers)",
        batches as f64 / dt,
        bytes_total as f64 / dt / (1 << 20) as f64
    );

    // ---- PJRT (optional) ----
    if let Ok(engine) = tfdatasvc::runtime::Engine::load(tfdatasvc::runtime::default_artifacts_dir()) {
        let m = engine.manifest().clone();
        engine.warm("preprocess_vision").unwrap();
        let (b, h, c) = (m.vision_batch, m.vision_hw, m.vision_c);
        let inputs = vec![
            Tensor::from_u8(vec![b, h, h, c], vec![100; b * h * h * c]),
            Tensor::from_f32(vec![b], &vec![0.0; b]),
            Tensor::from_f32(vec![b], &vec![0.0; b]),
            Tensor::from_f32(vec![b], &vec![1.0; b]),
        ];
        bench("pjrt: preprocess_vision (Pallas fused aug)", 100, || {
            std::hint::black_box(engine.execute("preprocess_vision", inputs.clone()).unwrap());
        });
        let mut trainer = tfdatasvc::train::PjrtTrainStep::new(engine, 0.05).unwrap();
        let toks: Vec<i32> = (0..m.model_batch * (m.model_seq + 1)).map(|i| (i % 250) as i32).collect();
        let tok_t = Tensor::from_i32(vec![m.model_batch, m.model_seq + 1], &toks);
        bench("pjrt: transformer train_step (fwd+bwd+sgd)", 50, || {
            trainer.step(tok_t.clone()).unwrap();
        });
    } else {
        println!("(artifacts not built; skipping PJRT benches)");
    }
    println!("micro_hotpath OK");
}
