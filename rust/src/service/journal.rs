//! Dispatcher write-ahead journal (§3.4) with snapshot compaction.
//!
//! Every dispatcher state change — dataset registration, job creation,
//! worker registration, client joins/releases — appends a CRC-framed
//! record before the change is acknowledged. On restart the dispatcher
//! restores its metadata from the newest *valid* [`DispatcherSnapshot`]
//! plus the journal suffix written after it, so restore cost is bounded
//! by live state + churn since the last checkpoint instead of the full
//! history. Split-assignment progress is deliberately *not* journaled:
//! the paper relaxes visitation to at-most-once, so an epoch's in-flight
//! splits may be lost on recovery.
//!
//! ## On-disk layout
//!
//! For a configured journal path `base`:
//!
//! ```text
//! base                 genesis suffix (records before the 1st snapshot)
//! base.snap-{N}        snapshot N: one CRC-framed DispatcherSnapshot
//! base.suffix-{N}      records appended after snapshot N was cut
//! base.snap-{N}.tmp    in-flight snapshot write (ignored; swept on open)
//! ```
//!
//! [`Journal::install_snapshot`] writes `snap-{N}` via temp-file +
//! atomic rename, then swaps the writer to a fresh `suffix-{N}` — all
//! under the writer lock, so no record is acknowledged between the
//! snapshot cut and the suffix open. The last **two** (snapshot, suffix)
//! pairs are retained; older files are deleted. That retention is what
//! makes the fallback ladder in [`Journal::restore`] complete: if
//! `snap-{N}` fails its CRC, `snap-{N-1}` + `suffix-{N-1}` + `suffix-{N}`
//! rebuild the identical state (suffix replay is deterministic).
//!
//! ## Corruption tolerance
//!
//! * A snapshot failing CRC/decode falls back to the previous snapshot,
//!   or to full-suffix replay from genesis if none is valid.
//! * A mid-suffix CRC mismatch keeps the longest valid record prefix
//!   instead of aborting recovery (the strict [`Journal::replay`] is
//!   kept for callers that want corruption to be loud).
//! * [`Journal::open`] *repairs* a corrupt suffix tail by truncating to
//!   the last valid record boundary before appending — otherwise records
//!   appended after the corrupt region would be unreachable by the very
//!   salvaged-prefix replay that tolerated it.
//!
//! Every degraded step is counted in [`RestoreOutcome::fallbacks`] so
//! the dispatcher can surface it (`dispatcher/restore_fallbacks`).

use crate::data::graph::GraphDef;
use crate::service::proto::{ProcessingMode, SharingMode, ShardingPolicy, WidthEpoch};
use crate::service::spill::SpillManifest;
use crate::util::crc32::Hasher;
use crate::wire::{Decode, Encode, Reader, WireError, WireResult, Writer};
use crate::wire_struct;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// One replayable state change.
#[derive(Debug, Clone, PartialEq)]
pub enum JournalRecord {
    RegisterDataset { dataset_id: u64, graph: GraphDef },
    CreateJob {
        job_id: u64,
        dataset_id: u64,
        job_name: String,
        sharding: ShardingPolicy,
        mode: ProcessingMode,
        num_consumers: u32,
        /// Ephemeral-sharing policy: replayed so fingerprint-matched
        /// attach keeps working across a dispatcher restart (§3.4 + §3.5).
        sharing: SharingMode,
        /// Worker ordering fixed at job creation (the coordinated-reads
        /// round-robin). Replayed so a restarted dispatcher rebuilds the
        /// round-lease table instead of resetting coordinated jobs to an
        /// unroutable state (§3.6 fault tolerance).
        worker_order: Vec<u64>,
        /// True when the job was created in snapshot-serve mode (its
        /// workers stream a committed snapshot instead of producing);
        /// replayed so a restarted dispatcher keeps handing snapshot
        /// tasks to re-registering workers.
        snapshot: bool,
    },
    RegisterWorker { worker_id: u64, addr: String },
    ClientJoined { job_id: u64, client_id: u64 },
    ClientReleased { job_id: u64, client_id: u64 },
    JobFinished { job_id: u64 },
    /// Round-lease table change for one coordinated job: the complete
    /// residue -> owner map after a failure reassignment or a revival
    /// re-balance. Replayed last-writer-wins over the `CreateJob`
    /// baseline, so dispatcher restart resumes the *current* lease
    /// layout; the materialization floor is deliberately not journaled —
    /// it is rebuilt from the first post-restart client heartbeats.
    RoundLeaseChanged { job_id: u64, residue_owners: Vec<u64> },
    /// Consumer-width change for one coordinated job (elastic
    /// membership): from `barrier_round` onward, rounds are keyed for
    /// `num_consumers` slots. Journaled *before* the change is published
    /// to workers or acknowledged to the caller, so a restarted
    /// dispatcher replays the full membership-epoch history and a
    /// heartbeating worker re-receives the schedule it may have missed.
    ConsumerSetChanged { job_id: u64, epoch: u32, barrier_round: u64, num_consumers: u32 },
    /// A fingerprint's epoch output was fully spilled and the per-worker
    /// manifests merged: from here on, an identical re-submitted
    /// pipeline (`sharing: auto`) may be served from storage instead of
    /// re-produced. Journaled *before* the snapshot is offered to any
    /// client; replayed last-writer-wins per fingerprint (`epoch` is
    /// monotone), so a restarted dispatcher keeps serving snapshots.
    SnapshotCommitted { fingerprint: u64, epoch: u64, manifest: SpillManifest },
    /// A worker entered (`draining: true`) or left (`false`) the
    /// two-phase graceful-drain state. Journaled *before* the state is
    /// acted on, so a restarted dispatcher resumes the drain — keeps the
    /// worker out of new-consumer routing and re-initiates pending lease
    /// handoffs — instead of silently re-admitting a half-drained worker.
    WorkerDrainChanged { worker_id: u64, draining: bool },
    /// A superseded spill snapshot's store objects
    /// (`spill/job-{job_id}/*`) were garbage-collected after a newer
    /// epoch snapshot committed for the same fingerprint. Journaled
    /// *before* the store deletes, and replayed by re-issuing them
    /// (`ObjectStore::delete` is idempotent), so a crash between append
    /// and delete cannot leak the objects.
    SpillSnapshotGced { job_id: u64 },
}

impl Encode for JournalRecord {
    fn encode(&self, w: &mut Writer) {
        match self {
            JournalRecord::RegisterDataset { dataset_id, graph } => {
                w.put_u8(0);
                w.put_u64(*dataset_id);
                graph.encode(w);
            }
            JournalRecord::CreateJob {
                job_id,
                dataset_id,
                job_name,
                sharding,
                mode,
                num_consumers,
                sharing,
                worker_order,
                snapshot,
            } => {
                w.put_u8(1);
                w.put_u64(*job_id);
                w.put_u64(*dataset_id);
                job_name.encode(w);
                sharding.encode(w);
                mode.encode(w);
                w.put_u32(*num_consumers);
                sharing.encode(w);
                worker_order.encode(w);
                snapshot.encode(w);
            }
            JournalRecord::RegisterWorker { worker_id, addr } => {
                w.put_u8(2);
                w.put_u64(*worker_id);
                addr.encode(w);
            }
            JournalRecord::ClientJoined { job_id, client_id } => {
                w.put_u8(3);
                w.put_u64(*job_id);
                w.put_u64(*client_id);
            }
            JournalRecord::ClientReleased { job_id, client_id } => {
                w.put_u8(4);
                w.put_u64(*job_id);
                w.put_u64(*client_id);
            }
            JournalRecord::JobFinished { job_id } => {
                w.put_u8(5);
                w.put_u64(*job_id);
            }
            JournalRecord::RoundLeaseChanged { job_id, residue_owners } => {
                w.put_u8(6);
                w.put_u64(*job_id);
                residue_owners.encode(w);
            }
            JournalRecord::ConsumerSetChanged { job_id, epoch, barrier_round, num_consumers } => {
                w.put_u8(7);
                w.put_u64(*job_id);
                w.put_u32(*epoch);
                w.put_u64(*barrier_round);
                w.put_u32(*num_consumers);
            }
            JournalRecord::SnapshotCommitted { fingerprint, epoch, manifest } => {
                w.put_u8(8);
                w.put_u64(*fingerprint);
                w.put_u64(*epoch);
                manifest.encode(w);
            }
            JournalRecord::WorkerDrainChanged { worker_id, draining } => {
                w.put_u8(9);
                w.put_u64(*worker_id);
                draining.encode(w);
            }
            JournalRecord::SpillSnapshotGced { job_id } => {
                w.put_u8(10);
                w.put_u64(*job_id);
            }
        }
    }
}

impl Decode for JournalRecord {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        Ok(match r.get_u8()? {
            0 => JournalRecord::RegisterDataset { dataset_id: r.get_u64()?, graph: GraphDef::decode(r)? },
            1 => JournalRecord::CreateJob {
                job_id: r.get_u64()?,
                dataset_id: r.get_u64()?,
                job_name: String::decode(r)?,
                sharding: ShardingPolicy::decode(r)?,
                mode: ProcessingMode::decode(r)?,
                num_consumers: r.get_u32()?,
                sharing: SharingMode::decode(r)?,
                worker_order: Vec::<u64>::decode(r)?,
                snapshot: bool::decode(r)?,
            },
            2 => JournalRecord::RegisterWorker { worker_id: r.get_u64()?, addr: String::decode(r)? },
            3 => JournalRecord::ClientJoined { job_id: r.get_u64()?, client_id: r.get_u64()? },
            4 => JournalRecord::ClientReleased { job_id: r.get_u64()?, client_id: r.get_u64()? },
            5 => JournalRecord::JobFinished { job_id: r.get_u64()? },
            6 => JournalRecord::RoundLeaseChanged {
                job_id: r.get_u64()?,
                residue_owners: Vec::<u64>::decode(r)?,
            },
            7 => JournalRecord::ConsumerSetChanged {
                job_id: r.get_u64()?,
                epoch: r.get_u32()?,
                barrier_round: r.get_u64()?,
                num_consumers: r.get_u32()?,
            },
            8 => JournalRecord::SnapshotCommitted {
                fingerprint: r.get_u64()?,
                epoch: r.get_u64()?,
                manifest: SpillManifest::decode(r)?,
            },
            9 => JournalRecord::WorkerDrainChanged {
                worker_id: r.get_u64()?,
                draining: bool::decode(r)?,
            },
            10 => JournalRecord::SpillSnapshotGced { job_id: r.get_u64()? },
            tag => return Err(WireError::BadTag { tag, ty: "JournalRecord" }),
        })
    }
}

/// One job's journal-derivable state inside a [`DispatcherSnapshot`].
/// Soft state (client round progress, in-flight handoffs, partial spill
/// manifests, pending delivery queues) is deliberately excluded — it is
/// rebuilt from post-restart heartbeats exactly as full-journal replay
/// rebuilds it.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotJob {
    pub job_id: u64,
    pub dataset_id: u64,
    pub job_name: String,
    pub sharding: ShardingPolicy,
    pub mode: ProcessingMode,
    pub num_consumers: u32,
    pub sharing: SharingMode,
    pub worker_order: Vec<u64>,
    pub residue_owners: Vec<u64>,
    /// Sorted, so encoding is canonical (HashSet order is not).
    pub clients: Vec<u64>,
    pub finished: bool,
    pub width_epochs: Vec<WidthEpoch>,
    pub snapshot_serve: bool,
    pub snapshot_committed: bool,
}
wire_struct!(SnapshotJob {
    job_id,
    dataset_id,
    job_name,
    sharding,
    mode,
    num_consumers,
    sharing,
    worker_order,
    residue_owners,
    clients,
    finished,
    width_epochs,
    snapshot_serve,
    snapshot_committed,
});

/// One worker's journal-derivable state inside a [`DispatcherSnapshot`].
/// Restored the same way `RegisterWorker` replays: optimistically alive,
/// unconfirmed until its first post-restart heartbeat.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotWorker {
    pub worker_id: u64,
    pub addr: String,
    pub draining: bool,
}
wire_struct!(SnapshotWorker { worker_id, addr, draining });

/// A `(dataset_id, job_name) -> job_id` named-job binding.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotNamedJob {
    pub dataset_id: u64,
    pub job_name: String,
    pub job_id: u64,
}
wire_struct!(SnapshotNamedJob { dataset_id, job_name, job_id });

/// The dispatcher's full replayable state at one point in time: what a
/// complete journal replay up to the cut would have rebuilt. All maps
/// are serialized as key-sorted vectors so the encoding is canonical —
/// the restore-equivalence property test relies on
/// `snapshot(meta_a) == snapshot(meta_b)` being byte-comparable.
#[derive(Debug, Clone, PartialEq)]
pub struct DispatcherSnapshot {
    /// Sorted by dataset id.
    pub datasets: Vec<(u64, GraphDef)>,
    /// Sorted by job id.
    pub jobs: Vec<SnapshotJob>,
    /// Sorted by (dataset_id, job_name).
    pub named_jobs: Vec<SnapshotNamedJob>,
    /// Sorted by worker id.
    pub workers: Vec<SnapshotWorker>,
    /// Committed fingerprint-keyed spill snapshots, sorted by fingerprint.
    pub spill_snapshots: Vec<(u64, SpillManifest)>,
    pub next_worker_id: u64,
    pub next_job_id: u64,
    pub next_client_id: u64,
}
wire_struct!(DispatcherSnapshot {
    datasets,
    jobs,
    named_jobs,
    workers,
    spill_snapshots,
    next_worker_id,
    next_job_id,
    next_client_id,
});

/// What [`Journal::restore`] recovered: the newest valid snapshot (if
/// any) plus the journal records appended after its cut, and how many
/// degraded steps (corrupt snapshot skipped, corrupt suffix truncated to
/// its valid prefix) the fallback ladder took.
#[derive(Debug, Default)]
pub struct RestoreOutcome {
    pub snapshot: Option<DispatcherSnapshot>,
    /// Sequence number of the snapshot restored from (0 = none; replay
    /// started from the genesis file).
    pub snapshot_seq: u64,
    /// Records to replay on top of the snapshot, oldest first.
    pub records: Vec<JournalRecord>,
    /// Count of corrupt snapshots skipped + corrupt suffixes truncated.
    pub fallbacks: u64,
}

fn crc_of(body: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(body);
    h.finalize()
}

/// How a frame scan over one file ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ScanEnd {
    /// Every byte belonged to a valid frame.
    Clean,
    /// Partial final frame (crash mid-append): normal, not corruption.
    TornTail,
    /// CRC or decode failure mid-file.
    Corrupt,
}

/// Walk `bytes` frame by frame. Returns the decoded records, the byte
/// length of the valid prefix (a record boundary), and how the scan
/// ended.
fn scan_frames(bytes: &[u8]) -> (Vec<JournalRecord>, usize, ScanEnd) {
    let mut out = Vec::new();
    let mut pos = 0usize;
    while pos < bytes.len() {
        if bytes.len() - pos < 8 {
            return (out, pos, ScanEnd::TornTail);
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        if bytes.len() - pos - 8 < len {
            return (out, pos, ScanEnd::TornTail);
        }
        let body = &bytes[pos + 8..pos + 8 + len];
        if crc_of(body) != crc {
            return (out, pos, ScanEnd::Corrupt);
        }
        match JournalRecord::from_bytes(body) {
            Ok(rec) => out.push(rec),
            Err(_) => return (out, pos, ScanEnd::Corrupt),
        }
        pos += 8 + len;
    }
    (out, pos, ScanEnd::Clean)
}

fn with_suffix_name(base: &Path, ext: &str) -> PathBuf {
    let mut name = base.file_name().unwrap_or_default().to_os_string();
    name.push(ext);
    base.with_file_name(name)
}

fn snap_path(base: &Path, seq: u64) -> PathBuf {
    with_suffix_name(base, &format!(".snap-{seq}"))
}

/// Suffix file holding the records appended after snapshot `seq` was
/// cut. Sequence 0 is the genesis file — the base path itself — so a
/// never-compacted journal is laid out exactly as before compaction
/// existed.
fn suffix_path(base: &Path, seq: u64) -> PathBuf {
    if seq == 0 {
        base.to_path_buf()
    } else {
        with_suffix_name(base, &format!(".suffix-{seq}"))
    }
}

/// Sequence numbers present on disk for `prefix` files
/// (`{base}.{kind}-{seq}`), ignoring `.tmp` leftovers.
fn list_seqs(base: &Path, kind: &str) -> Vec<u64> {
    let dir = match base.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => PathBuf::from("."),
    };
    let fname = match base.file_name().and_then(|n| n.to_str()) {
        Some(n) => n.to_string(),
        None => return vec![],
    };
    let prefix = format!("{fname}.{kind}-");
    let mut seqs = Vec::new();
    if let Ok(entries) = std::fs::read_dir(&dir) {
        for e in entries.flatten() {
            if let Some(name) = e.file_name().to_str() {
                if let Some(rest) = name.strip_prefix(&prefix) {
                    if let Ok(seq) = rest.parse::<u64>() {
                        seqs.push(seq);
                    }
                }
            }
        }
    }
    seqs.sort_unstable();
    seqs
}

/// Append-only journal with snapshot compaction. Thread-safe; every
/// append is flushed before returning (write-ahead semantics).
pub struct Journal {
    base: PathBuf,
    inner: Mutex<Active>,
}

struct Active {
    writer: BufWriter<File>,
    /// Snapshot sequence the current suffix belongs to (0 = genesis).
    seq: u64,
    suffix_bytes: u64,
    suffix_records: u64,
}

impl Journal {
    /// Open (creating if missing) the journal rooted at `path`. Appends
    /// go to the suffix of the newest on-disk snapshot (genesis if
    /// none). A corrupt suffix tail is **repaired** — truncated back to
    /// the last valid record boundary — so records appended from here
    /// on land exactly where a salvaged-prefix restore replays to;
    /// without the repair they would sit behind the corrupt region,
    /// unreachable forever. Stale `.tmp` snapshot files are swept.
    pub fn open(path: impl AsRef<Path>) -> std::io::Result<Journal> {
        let base = path.as_ref().to_path_buf();
        if let Some(parent) = base.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        // Sweep snapshot temp files from a crash mid-install: the rename
        // never happened, so they are invisible to restore and dead weight.
        if let Some(dir) = base.parent() {
            if let (Some(fname), Ok(entries)) =
                (base.file_name().and_then(|n| n.to_str()), std::fs::read_dir(dir))
            {
                for e in entries.flatten() {
                    if let Some(name) = e.file_name().to_str() {
                        if name.starts_with(&format!("{fname}.snap-")) && name.ends_with(".tmp") {
                            let _ = std::fs::remove_file(e.path());
                        }
                    }
                }
            }
        }
        let seq = list_seqs(&base, "snap").into_iter().max().unwrap_or(0);
        let sp = suffix_path(&base, seq);
        let (suffix_bytes, suffix_records) = match std::fs::read(&sp) {
            Ok(bytes) => {
                let (recs, valid_len, _) = scan_frames(&bytes);
                if valid_len < bytes.len() {
                    let f = OpenOptions::new().write(true).open(&sp)?;
                    f.set_len(valid_len as u64)?;
                    f.sync_all()?;
                }
                (valid_len as u64, recs.len() as u64)
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => (0, 0),
            Err(e) => return Err(e),
        };
        let file = OpenOptions::new().create(true).append(true).open(&sp)?;
        Ok(Journal {
            base,
            inner: Mutex::new(Active {
                writer: BufWriter::new(file),
                seq,
                suffix_bytes,
                suffix_records,
            }),
        })
    }

    /// Append one record (length + crc framed) and flush.
    pub fn append(&self, rec: &JournalRecord) -> std::io::Result<()> {
        let body = rec.to_bytes();
        let crc = crc_of(&body);
        let mut a = self.inner.lock().unwrap();
        a.writer.write_all(&(body.len() as u32).to_le_bytes())?;
        a.writer.write_all(&crc.to_le_bytes())?;
        a.writer.write_all(&body)?;
        a.writer.flush()?;
        a.suffix_bytes += 8 + body.len() as u64;
        a.suffix_records += 1;
        Ok(())
    }

    /// Bytes appended to the current suffix since the last snapshot —
    /// the compaction trigger input.
    pub fn suffix_bytes(&self) -> u64 {
        self.inner.lock().unwrap().suffix_bytes
    }

    /// Records appended to the current suffix since the last snapshot.
    pub fn suffix_records(&self) -> u64 {
        self.inner.lock().unwrap().suffix_records
    }

    /// Sequence of the newest installed snapshot (0 = none yet).
    pub fn snapshot_seq(&self) -> u64 {
        self.inner.lock().unwrap().seq
    }

    /// Install `snap` as the next checkpoint: write it CRC-framed to
    /// `snap-{seq+1}` via temp-file + atomic rename, swap the writer to
    /// a fresh `suffix-{seq+1}`, and delete files older than the
    /// previous (snapshot, suffix) pair. Holds the writer lock
    /// throughout, so concurrent `append`s serialize either entirely
    /// before the cut (captured by `snap` — the caller cuts it under
    /// the same state lock its appenders hold) or entirely after (into
    /// the new suffix): no record is ever acknowledged into a file the
    /// install is about to retire. Returns the new sequence.
    pub fn install_snapshot(&self, snap: &DispatcherSnapshot) -> std::io::Result<u64> {
        let mut a = self.inner.lock().unwrap();
        let new_seq = a.seq + 1;
        let body = snap.to_bytes();
        let crc = crc_of(&body);
        let tmp = with_suffix_name(&self.base, &format!(".snap-{new_seq}.tmp"));
        {
            let mut f = File::create(&tmp)?;
            f.write_all(&(body.len() as u32).to_le_bytes())?;
            f.write_all(&crc.to_le_bytes())?;
            f.write_all(&body)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, snap_path(&self.base, new_seq))?;
        let sp = suffix_path(&self.base, new_seq);
        // Truncate-create (a crashed earlier install may have left one),
        // then reopen in append mode for the writer.
        File::create(&sp)?;
        a.writer = BufWriter::new(OpenOptions::new().append(true).open(&sp)?);
        a.seq = new_seq;
        a.suffix_bytes = 0;
        a.suffix_records = 0;
        // Retention: keep (new_seq, new_seq-1); anything older can no
        // longer be reached by the fallback ladder's one-step-back.
        if new_seq >= 2 {
            for s in list_seqs(&self.base, "snap") {
                if s <= new_seq - 2 {
                    let _ = std::fs::remove_file(snap_path(&self.base, s));
                }
            }
            for s in 0..=new_seq - 2 {
                let p = suffix_path(&self.base, s);
                let _ = std::fs::remove_file(p);
            }
        }
        Ok(new_seq)
    }

    /// Replay all intact records of one plain journal file. A torn tail
    /// (partial final record, e.g. crash mid-append) is tolerated and
    /// ignored; corruption in the middle is an error. This is the
    /// strict, pre-compaction entry point — the dispatcher's tolerant
    /// path is [`Journal::restore`].
    pub fn replay(path: impl AsRef<Path>) -> std::io::Result<Vec<JournalRecord>> {
        let mut bytes = Vec::new();
        match File::open(path.as_ref()) {
            Ok(mut f) => {
                f.read_to_end(&mut bytes)?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(vec![]),
            Err(e) => return Err(e),
        }
        let (out, pos, end) = scan_frames(&bytes);
        if end == ScanEnd::Corrupt {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("journal crc mismatch at byte {pos}"),
            ));
        }
        Ok(out)
    }

    /// Load and CRC-check one snapshot file.
    fn load_snapshot(path: &Path) -> std::io::Result<DispatcherSnapshot> {
        let bytes = std::fs::read(path)?;
        if bytes.len() < 8 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "snapshot shorter than its frame header",
            ));
        }
        let len = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if bytes.len() - 8 < len {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "snapshot body truncated",
            ));
        }
        let body = &bytes[8..8 + len];
        if crc_of(body) != crc {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "snapshot crc mismatch",
            ));
        }
        DispatcherSnapshot::from_bytes(body).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, format!("snapshot decode: {e}"))
        })
    }

    /// Corruption-tolerant restore: walk the fallback ladder.
    ///
    /// 1. Try snapshots newest-first; a snapshot failing CRC/decode is
    ///    skipped (counted as a fallback).
    /// 2. From the chosen snapshot `S` (or genesis if none validated),
    ///    replay the suffix chain `S, S+1, …` ascending — replay is
    ///    deterministic, so replaying `suffix-{S}` on top of snapshot
    ///    `S` re-derives exactly the state snapshot `S+1` captured.
    /// 3. A mid-suffix CRC mismatch keeps the longest valid prefix and
    ///    stops the chain there (counted as a fallback) instead of
    ///    aborting recovery.
    ///
    /// Never returns an error for corruption — only for real I/O
    /// failures reading an existing file.
    pub fn restore(path: impl AsRef<Path>) -> std::io::Result<RestoreOutcome> {
        let base = path.as_ref();
        let mut out = RestoreOutcome::default();
        let snap_seqs = list_seqs(base, "snap");
        for &seq in snap_seqs.iter().rev() {
            match Self::load_snapshot(&snap_path(base, seq)) {
                Ok(s) => {
                    out.snapshot = Some(s);
                    out.snapshot_seq = seq;
                    break;
                }
                Err(_) => out.fallbacks += 1,
            }
        }
        let start = out.snapshot_seq;
        let end = snap_seqs
            .last()
            .copied()
            .unwrap_or(0)
            .max(list_seqs(base, "suffix").last().copied().unwrap_or(0))
            .max(start);
        for seq in start..=end {
            let bytes = match std::fs::read(suffix_path(base, seq)) {
                Ok(b) => b,
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => return Err(e),
            };
            let (recs, _, scan_end) = scan_frames(&bytes);
            out.records.extend(recs);
            if scan_end == ScanEnd::Corrupt {
                // Records past the corrupt region (including any later
                // suffix, written strictly after them) can no longer be
                // applied in order: keep the longest consistent prefix.
                out.fallbacks += 1;
                break;
            }
        }
        Ok(out)
    }

    pub fn path(&self) -> &Path {
        &self.base
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::graph::PipelineBuilder;

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("tfdatasvc-journal-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("{name}-{}", std::process::id()));
        cleanup(&p);
        p
    }

    /// Remove the base file and every snapshot/suffix sibling.
    fn cleanup(p: &Path) {
        let _ = std::fs::remove_file(p);
        for kind in ["snap", "suffix"] {
            for seq in list_seqs(p, kind) {
                let _ = std::fs::remove_file(with_suffix_name(p, &format!(".{kind}-{seq}")));
            }
        }
    }

    fn sample_records() -> Vec<JournalRecord> {
        vec![
            JournalRecord::RegisterDataset {
                dataset_id: 11,
                graph: PipelineBuilder::source_range(5).batch(2).build(),
            },
            JournalRecord::CreateJob {
                job_id: 1,
                dataset_id: 11,
                job_name: "shared".into(),
                sharding: ShardingPolicy::Dynamic,
                mode: ProcessingMode::Independent,
                num_consumers: 0,
                sharing: SharingMode::Auto,
                worker_order: vec![5, 9],
                snapshot: false,
            },
            JournalRecord::RegisterWorker { worker_id: 5, addr: "127.0.0.1:4000".into() },
            JournalRecord::ClientJoined { job_id: 1, client_id: 2 },
            JournalRecord::ClientReleased { job_id: 1, client_id: 2 },
            JournalRecord::RoundLeaseChanged { job_id: 1, residue_owners: vec![5, 5] },
            JournalRecord::ConsumerSetChanged {
                job_id: 1,
                epoch: 1,
                barrier_round: 12,
                num_consumers: 3,
            },
            JournalRecord::SnapshotCommitted {
                fingerprint: 11,
                epoch: 0,
                manifest: crate::service::spill::SpillManifest {
                    fingerprint: 11,
                    job_id: 1,
                    epoch: 0,
                    total_elements: 4,
                    complete: true,
                    segments: vec![crate::service::spill::SegmentMeta {
                        key: "spill/job-1/data".into(),
                        offset: 0,
                        len: 32,
                        start_seq: 0,
                        num_elements: 4,
                        crc32: 0xdead_beef,
                    }],
                },
            },
            JournalRecord::WorkerDrainChanged { worker_id: 5, draining: true },
            JournalRecord::WorkerDrainChanged { worker_id: 5, draining: false },
            JournalRecord::SpillSnapshotGced { job_id: 1 },
            JournalRecord::JobFinished { job_id: 1 },
        ]
    }

    fn sample_snapshot() -> DispatcherSnapshot {
        DispatcherSnapshot {
            datasets: vec![(11, PipelineBuilder::source_range(5).batch(2).build())],
            jobs: vec![SnapshotJob {
                job_id: 1,
                dataset_id: 11,
                job_name: "shared".into(),
                sharding: ShardingPolicy::Dynamic,
                mode: ProcessingMode::Coordinated,
                num_consumers: 2,
                sharing: SharingMode::Auto,
                worker_order: vec![5, 9],
                residue_owners: vec![5, 5],
                clients: vec![2, 3],
                finished: false,
                width_epochs: vec![WidthEpoch { epoch: 0, barrier_round: 0, num_consumers: 2 }],
                snapshot_serve: false,
                snapshot_committed: false,
            }],
            named_jobs: vec![SnapshotNamedJob {
                dataset_id: 11,
                job_name: "shared".into(),
                job_id: 1,
            }],
            workers: vec![SnapshotWorker { worker_id: 5, addr: "127.0.0.1:4000".into(), draining: false }],
            spill_snapshots: vec![],
            next_worker_id: 6,
            next_job_id: 2,
            next_client_id: 4,
        }
    }

    #[test]
    fn append_replay_roundtrip() {
        let p = tmpfile("roundtrip");
        let j = Journal::open(&p).unwrap();
        let recs = sample_records();
        for r in &recs {
            j.append(r).unwrap();
        }
        drop(j);
        assert_eq!(Journal::replay(&p).unwrap(), recs);
        cleanup(&p);
    }

    #[test]
    fn replay_missing_file_is_empty() {
        assert!(Journal::replay("/nonexistent/journal").unwrap().is_empty());
    }

    #[test]
    fn torn_tail_tolerated() {
        let p = tmpfile("torn");
        let j = Journal::open(&p).unwrap();
        let recs = sample_records();
        for r in &recs {
            j.append(r).unwrap();
        }
        drop(j);
        // Truncate mid-record to simulate a crash during append.
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 3]).unwrap();
        let replayed = Journal::replay(&p).unwrap();
        assert_eq!(replayed, recs[..recs.len() - 1]);
        cleanup(&p);
    }

    #[test]
    fn mid_file_corruption_is_error() {
        let p = tmpfile("corrupt");
        let j = Journal::open(&p).unwrap();
        for r in sample_records() {
            j.append(&r).unwrap();
        }
        drop(j);
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[10] ^= 0xff; // flip a byte in the first record's body
        std::fs::write(&p, &bytes).unwrap();
        assert!(Journal::replay(&p).is_err());
        cleanup(&p);
    }

    #[test]
    fn reopen_appends_not_truncates() {
        let p = tmpfile("reopen");
        {
            let j = Journal::open(&p).unwrap();
            j.append(&JournalRecord::JobFinished { job_id: 1 }).unwrap();
        }
        {
            let j = Journal::open(&p).unwrap();
            j.append(&JournalRecord::JobFinished { job_id: 2 }).unwrap();
        }
        let recs = Journal::replay(&p).unwrap();
        assert_eq!(
            recs,
            vec![JournalRecord::JobFinished { job_id: 1 }, JournalRecord::JobFinished { job_id: 2 }]
        );
        cleanup(&p);
    }

    #[test]
    fn snapshot_wire_roundtrip() {
        let s = sample_snapshot();
        let b = s.to_bytes();
        assert_eq!(DispatcherSnapshot::from_bytes(&b).unwrap(), s);
    }

    #[test]
    fn restore_without_snapshot_replays_genesis() {
        let p = tmpfile("restore-genesis");
        let j = Journal::open(&p).unwrap();
        let recs = sample_records();
        for r in &recs {
            j.append(r).unwrap();
        }
        drop(j);
        let out = Journal::restore(&p).unwrap();
        assert!(out.snapshot.is_none());
        assert_eq!(out.snapshot_seq, 0);
        assert_eq!(out.records, recs);
        assert_eq!(out.fallbacks, 0);
        cleanup(&p);
    }

    #[test]
    fn snapshot_bounds_restore_to_suffix() {
        let p = tmpfile("restore-suffix");
        let j = Journal::open(&p).unwrap();
        for r in sample_records() {
            j.append(&r).unwrap();
        }
        assert!(j.suffix_bytes() > 0);
        let seq = j.install_snapshot(&sample_snapshot()).unwrap();
        assert_eq!(seq, 1);
        assert_eq!(j.suffix_bytes(), 0);
        j.append(&JournalRecord::JobFinished { job_id: 7 }).unwrap();
        drop(j);
        let out = Journal::restore(&p).unwrap();
        assert_eq!(out.snapshot, Some(sample_snapshot()));
        assert_eq!(out.snapshot_seq, 1);
        assert_eq!(out.records, vec![JournalRecord::JobFinished { job_id: 7 }]);
        assert_eq!(out.fallbacks, 0);
        cleanup(&p);
    }

    #[test]
    fn corrupt_newest_snapshot_falls_back_to_previous() {
        let p = tmpfile("restore-fallback");
        let j = Journal::open(&p).unwrap();
        for r in sample_records() {
            j.append(&r).unwrap();
        }
        j.install_snapshot(&sample_snapshot()).unwrap();
        // Records between snapshot 1 and 2 — captured by snapshot 2, but
        // also replayable from suffix-1 when snapshot 2 is corrupt.
        j.append(&JournalRecord::JobFinished { job_id: 8 }).unwrap();
        let mut snap2 = sample_snapshot();
        snap2.next_job_id = 9;
        j.install_snapshot(&snap2).unwrap();
        j.append(&JournalRecord::JobFinished { job_id: 9 }).unwrap();
        drop(j);
        // Corrupt snapshot 2's body.
        let sp2 = snap_path(&p, 2);
        let mut bytes = std::fs::read(&sp2).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&sp2, &bytes).unwrap();

        let out = Journal::restore(&p).unwrap();
        assert_eq!(out.snapshot, Some(sample_snapshot()), "must fall back to snapshot 1");
        assert_eq!(out.snapshot_seq, 1);
        // suffix-1 (the records snapshot 2 had absorbed) + suffix-2.
        assert_eq!(
            out.records,
            vec![
                JournalRecord::JobFinished { job_id: 8 },
                JournalRecord::JobFinished { job_id: 9 }
            ]
        );
        assert_eq!(out.fallbacks, 1);
        cleanup(&p);
    }

    #[test]
    fn mid_suffix_corruption_keeps_longest_valid_prefix() {
        let p = tmpfile("restore-prefix");
        let j = Journal::open(&p).unwrap();
        for id in 1..=5u64 {
            j.append(&JournalRecord::JobFinished { job_id: id }).unwrap();
        }
        drop(j);
        let mut bytes = std::fs::read(&p).unwrap();
        // One JobFinished frame is 8 (header) + 9 (body) bytes; corrupt
        // the third record's body.
        bytes[2 * 17 + 8] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();
        let out = Journal::restore(&p).unwrap();
        assert_eq!(
            out.records,
            vec![JournalRecord::JobFinished { job_id: 1 }, JournalRecord::JobFinished { job_id: 2 }]
        );
        assert_eq!(out.fallbacks, 1);
        cleanup(&p);
    }

    #[test]
    fn open_repairs_corrupt_tail_before_appending() {
        let p = tmpfile("repair");
        {
            let j = Journal::open(&p).unwrap();
            for id in 1..=3u64 {
                j.append(&JournalRecord::JobFinished { job_id: id }).unwrap();
            }
        }
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[2 * 17 + 8] ^= 0xff; // corrupt record 3's body
        std::fs::write(&p, &bytes).unwrap();
        // Reopen: the corrupt tail must be truncated, so this append
        // lands at the salvaged-prefix boundary and is replayable.
        {
            let j = Journal::open(&p).unwrap();
            j.append(&JournalRecord::JobFinished { job_id: 4 }).unwrap();
        }
        let out = Journal::restore(&p).unwrap();
        assert_eq!(
            out.records,
            vec![
                JournalRecord::JobFinished { job_id: 1 },
                JournalRecord::JobFinished { job_id: 2 },
                JournalRecord::JobFinished { job_id: 4 }
            ]
        );
        assert_eq!(out.fallbacks, 0, "repair happened at open, restore sees a clean file");
        cleanup(&p);
    }

    #[test]
    fn retention_keeps_two_pairs() {
        let p = tmpfile("retention");
        let j = Journal::open(&p).unwrap();
        for seq in 1..=4u64 {
            j.append(&JournalRecord::JobFinished { job_id: seq }).unwrap();
            let mut s = sample_snapshot();
            s.next_job_id = seq + 1;
            assert_eq!(j.install_snapshot(&s).unwrap(), seq);
        }
        drop(j);
        assert_eq!(list_seqs(&p, "snap"), vec![3, 4]);
        assert_eq!(list_seqs(&p, "suffix"), vec![3, 4]);
        assert!(!p.exists(), "genesis file retired by retention");
        let out = Journal::restore(&p).unwrap();
        assert_eq!(out.snapshot_seq, 4);
        assert_eq!(out.snapshot.unwrap().next_job_id, 5);
        assert!(out.records.is_empty());
        cleanup(&p);
    }

    #[test]
    fn reopen_after_snapshot_appends_to_newest_suffix() {
        let p = tmpfile("reopen-snap");
        {
            let j = Journal::open(&p).unwrap();
            j.append(&JournalRecord::JobFinished { job_id: 1 }).unwrap();
            j.install_snapshot(&sample_snapshot()).unwrap();
            j.append(&JournalRecord::JobFinished { job_id: 2 }).unwrap();
        }
        {
            let j = Journal::open(&p).unwrap();
            assert_eq!(j.snapshot_seq(), 1);
            assert_eq!(j.suffix_records(), 1);
            j.append(&JournalRecord::JobFinished { job_id: 3 }).unwrap();
        }
        let out = Journal::restore(&p).unwrap();
        assert_eq!(out.snapshot_seq, 1);
        assert_eq!(
            out.records,
            vec![JournalRecord::JobFinished { job_id: 2 }, JournalRecord::JobFinished { job_id: 3 }]
        );
        cleanup(&p);
    }
}
