//! Fig. 10: normalized preprocessing cost of hyperparameter-tuning jobs
//! under deployment modes A (shared + sharing), B (shared, no sharing),
//! C (dedicated per job), for k in {1,2,4,8,16}.
//!
//! Paper: A flat at 1x (tested to 64 jobs); B fine to 4 jobs then job
//! time grows 1.75x @ 8 and 3x @ 16; C cost grows linearly.
//!
//! Two halves:
//! 1. the `sim::sharing` cost model reproducing the figure, and
//! 2. a **real-service cross-check**: k in-process jobs against a live
//!    dispatcher/worker, once with `sharing: auto` (mode A — all k attach
//!    to one fingerprint-matched job) and once with `sharing: off`
//!    (mode B — k dedicated productions on the same pool), printing
//!    measured production cost next to the sim prediction so the model
//!    and the implementation keep each other honest.
//!
//! `--smoke` shrinks the dataset and k for CI.

use std::sync::Arc;
use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::metrics::write_csv_rows;
use tfdatasvc::orchestrator::Cell;
use tfdatasvc::rpc::{call_typed, Pool};
use tfdatasvc::service::dispatcher::DispatcherConfig;
use tfdatasvc::service::proto::{
    worker_methods, SharingMode, ShardingPolicy, WorkerStatusReq, WorkerStatusResp,
};
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::sim::models::model;
use tfdatasvc::sim::sharing::{mode_a, mode_b, mode_c, sequential_sharing_cost, SharingConfig};
use tfdatasvc::storage::dataset::{generate_vision, VisionGenConfig};
use tfdatasvc::storage::ObjectStore;

struct RealRun {
    /// Elements the worker pool produced, total.
    produced: u64,
    /// Elements all clients consumed, total.
    consumed: u64,
    /// How many clients attached to an existing job.
    attaches: usize,
    distinct_jobs: usize,
}

/// Run k concurrent anonymous clients over one identical pipeline on a
/// fresh single-worker cell, with the given sharing policy.
fn run_real(k: usize, sharing: SharingMode, shards: usize, samples_per_shard: usize) -> RealRun {
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: shards, samples_per_shard, ..Default::default() },
    );
    let cell =
        Arc::new(Cell::new(store, UdfRegistry::with_builtins(), DispatcherConfig::default()).unwrap());
    cell.set_worker_config_mutator(|c| c.cache_window = 4096);
    cell.scale_to(1).unwrap();
    let graph = PipelineBuilder::source_vision(spec).batch(8).build();

    // Join all k clients first (so every attach targets a live job), then
    // drain concurrently.
    let iters: Vec<_> = (0..k)
        .map(|_| {
            let c = ServiceClient::new(&cell.dispatcher_addr());
            c.distribute(
                &graph,
                ServiceClientConfig {
                    sharding: ShardingPolicy::Dynamic,
                    sharing,
                    ..Default::default()
                },
            )
            .unwrap()
        })
        .collect();
    let handles: Vec<_> = iters
        .into_iter()
        .map(|mut it| {
            std::thread::spawn(move || {
                let mut n = 0u64;
                while let Ok(Some(_)) = it.next() {
                    n += 1;
                }
                (n, it.job_id(), it.attached())
            })
        })
        .collect();
    let results: Vec<(u64, u64, bool)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let consumed = results.iter().map(|r| r.0).sum();
    let attaches = results.iter().filter(|r| r.2).count();
    let mut jobs: Vec<u64> = results.iter().map(|r| r.1).collect();
    jobs.sort_unstable();
    jobs.dedup();

    let pool = Pool::with_defaults();
    let status: WorkerStatusResp = call_typed(
        &pool,
        &cell.worker_addrs()[0],
        worker_methods::WORKER_STATUS,
        &WorkerStatusReq {},
        std::time::Duration::from_secs(5),
    )
    .unwrap();
    RealRun { produced: status.elements_produced, consumed, attaches, distinct_jobs: jobs.len() }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let m = model("M4");
    let cfg = SharingConfig::default();
    println!("=== Fig 10: preprocessing cost by deployment mode (sim) ===");
    println!("{:>4} {:>12} {:>12} {:>12} {:>14}", "k", "A(shared)", "B(no share)", "C(dedicated)", "B slowdown");
    let mut rows = Vec::new();
    for k in [1usize, 2, 4, 8, 16] {
        let a = mode_a(m, &cfg, k);
        let b = mode_b(m, &cfg, k);
        let c = mode_c(m, &cfg, k);
        println!(
            "{:>4} {:>12.2} {:>12.2} {:>12.2} {:>13.2}x",
            k,
            a.preprocessing_cost,
            b.preprocessing_cost,
            c.preprocessing_cost,
            1.0 / b.per_job_throughput_frac
        );
        rows.push(vec![
            k.to_string(),
            format!("{:.3}", a.preprocessing_cost),
            format!("{:.3}", b.preprocessing_cost),
            format!("{:.3}", c.preprocessing_cost),
        ]);
    }
    // Paper anchor points.
    let b8 = mode_b(m, &cfg, 8);
    let b16 = mode_b(m, &cfg, 16);
    assert!((1.0 / b8.per_job_throughput_frac - 1.75).abs() < 0.3);
    assert!((1.0 / b16.per_job_throughput_frac - 3.0).abs() < 0.35);
    assert_eq!(mode_a(m, &cfg, 64).preprocessing_cost, 1.0, "A flat to 64 jobs");
    println!(
        "worst-case sequential sharing (cache 1% of dataset, k=16): {:.2}x of one job's cost (vs 16x unshared)",
        sequential_sharing_cost(16, 0.01, 1.0)
    );
    write_csv_rows("out/fig10.csv", "k,mode_a_cost,mode_b_cost,mode_c_cost", &rows).unwrap();

    // ---- Real-service cross-check: fingerprint sharing vs dedicated ----
    let (shards, samples, k) = if smoke { (2, 16, 2) } else { (4, 32, 4) };
    let epoch = (shards * samples / 8) as u64; // batches per epoch

    let shared = run_real(k, SharingMode::Auto, shards, samples);
    assert_eq!(shared.distinct_jobs, 1, "auto sharing converged on one job");
    assert_eq!(shared.attaches, k - 1, "k-1 clients attached");
    assert_eq!(shared.consumed, k as u64 * epoch, "every client drained the epoch");
    assert!(
        shared.produced as f64 <= 1.1 * epoch as f64,
        "mode A single production: produced {} vs epoch {epoch}",
        shared.produced
    );

    let dedicated = run_real(k, SharingMode::Off, shards, samples);
    assert_eq!(dedicated.distinct_jobs, k, "opt-out keeps k dedicated jobs");
    assert_eq!(dedicated.attaches, 0);
    assert_eq!(dedicated.consumed, k as u64 * epoch);
    assert!(
        dedicated.produced as f64 >= 0.9 * (k as u64 * epoch) as f64,
        "mode B k productions: produced {} vs k*epoch {}",
        dedicated.produced,
        k as u64 * epoch
    );

    let measured_a = shared.produced as f64 / epoch as f64;
    let measured_b = dedicated.produced as f64 / epoch as f64;
    let sim_a = mode_a(m, &cfg, k).preprocessing_cost;
    let sim_b_reads = mode_b(m, &cfg, k).storage_reads_rel;
    println!("=== Fig 10: real-service cross-check (k={k}, epoch={epoch} batches) ===");
    println!(
        "mode A (sharing auto): measured production cost {measured_a:.2}x, sim predicts {sim_a:.2}x"
    );
    println!(
        "mode B (sharing off):  measured production cost {measured_b:.2}x, sim predicts {sim_b_reads:.0}x productions"
    );
    write_csv_rows(
        "out/fig10_real.csv",
        "k,measured_a_cost,sim_a_cost,measured_b_cost,sim_b_productions",
        &[vec![
            k.to_string(),
            format!("{measured_a:.3}"),
            format!("{sim_a:.3}"),
            format!("{measured_b:.3}"),
            format!("{sim_b_reads:.3}"),
        ]],
    )
    .unwrap();
    assert!((measured_a - sim_a).abs() <= 0.1, "sim and implementation agree on mode A");
    assert!(
        (measured_b - sim_b_reads).abs() <= 0.1 * sim_b_reads,
        "sim and implementation agree on mode B production count"
    );
    println!("fig10 OK -> out/fig10.csv, out/fig10_real.csv");
}
