//! Discrete-event simulation of one training job.
//!
//! Entities: `n_workers` preprocessing workers (rate-limited batch
//! producers), one logical client pool consuming batches at accelerator
//! speed through a bounded buffer with backpressure. Per-worker rates are
//! calibrated directly from the paper's observables
//! ([`ModelSpec::per_worker_bps`], from the Fig. 9 sweep for M1 and
//! `service_bps / paper_workers` otherwise); colocated mode produces at
//! the measured colocated rate. Per batch,
//!
//! ```text
//! t_batch = max(1 / rate, io_time)                   (pipelined I/O)
//! ```
//!
//! where `io_time` models storage reads (latency + bytes/bandwidth; the
//! §4.2 cross-region scenario). The client additionally caps throughput
//! at `service_bps` when disaggregated — the deserialize/copy ingest
//! bound that left M2 8% short of ideal.
//!
//! Outputs: steady-state throughput, accelerator utilization/stall, and
//! mean worker utilization (the autoscaler signal).

use super::models::ModelSpec;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Simulation setup for one job.
#[derive(Debug, Clone)]
pub struct JobSimConfig {
    /// Remote preprocessing workers. 0 = colocated mode.
    pub n_workers: usize,
    /// Client-side buffer capacity (batches) — backpressure bound.
    pub buffer_cap: usize,
    /// Per-batch storage I/O time (seconds) for whoever preprocesses;
    /// ~0 in-region, dominant cross-region (§4.2).
    pub io_time_per_batch: f64,
    /// Steps to simulate (each consumes `accelerators` batches).
    pub steps: u64,
}

impl Default for JobSimConfig {
    fn default() -> Self {
        JobSimConfig { n_workers: 0, buffer_cap: 64, io_time_per_batch: 0.0, steps: 400 }
    }
}

/// Simulation outputs.
#[derive(Debug, Clone)]
pub struct JobSimResult {
    pub throughput_bps: f64,
    /// Fraction of wall time accelerators were computing.
    pub accel_utilization: f64,
    /// Fraction of wall time accelerators waited for data.
    pub accel_stall_fraction: f64,
    /// Mean worker busy fraction (CPU utilization signal).
    pub worker_utilization: f64,
    pub sim_seconds: f64,
}

#[derive(Debug, PartialEq)]
struct Event {
    time: f64,
    kind: EventKind,
}

#[derive(Debug, PartialEq, Eq, Clone, Copy)]
enum EventKind {
    BatchReady(usize),
    StepDone,
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time.partial_cmp(&other.time).unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// Run the DES.
pub fn simulate_job(model: &ModelSpec, cfg: &JobSimConfig) -> JobSimResult {
    let colocated = cfg.n_workers == 0;
    let producers = if colocated { 1 } else { cfg.n_workers };
    let base_rate = if colocated { model.colocated_bps } else { model.per_worker_bps };
    let batch_time = (1.0 / base_rate).max(cfg.io_time_per_batch);
    // Client ingest bound (deserialize + copies) only applies to remote
    // batches; it is what keeps M2 8% below ideal.
    let ingest_floor = if colocated {
        0.0
    } else {
        model.accelerators as f64 / model.service_bps
    };
    let step_time = model.accel_step_time().max(ingest_floor);
    let per_step_batches = model.accelerators.max(1) as u64;

    let mut queue: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
    for w in 0..producers {
        let t = batch_time * (1.0 + w as f64 / producers as f64);
        queue.push(Reverse(Event { time: t, kind: EventKind::BatchReady(w) }));
    }

    let mut now = 0.0f64;
    let mut buffered = 0u64;
    let mut steps_done = 0u64;
    let mut accel_busy_until = 0.0f64;
    let mut accel_busy_total = 0.0f64;
    let mut batches_produced = 0u64;
    let mut accel_idle_since: Option<f64> = Some(0.0);
    let mut stall_total = 0.0f64;
    // Steady-state measurement starts at the first step (excludes the
    // pipeline warm-up, which the paper's steady-state batches/s also
    // excludes).
    let mut first_step_start: Option<f64> = None;
    // Workers blocked on a full buffer (backpressure).
    let mut stalled: Vec<usize> = Vec::new();

    while steps_done < cfg.steps {
        let Some(Reverse(ev)) = queue.pop() else { break };
        now = ev.time;
        match ev.kind {
            EventKind::BatchReady(w) => {
                batches_produced += 1;
                if buffered < cfg.buffer_cap as u64 {
                    buffered += 1;
                    queue.push(Reverse(Event { time: now + batch_time, kind: EventKind::BatchReady(w) }));
                } else {
                    // Buffer full: worker parks, holding its finished
                    // batch, until a step drains the buffer.
                    stalled.push(w);
                    batches_produced -= 1; // counted on delivery instead
                }
                if now >= accel_busy_until && buffered >= per_step_batches {
                    if let Some(since) = accel_idle_since.take() {
                        if first_step_start.is_some() {
                            stall_total += now - since;
                        }
                    }
                    first_step_start.get_or_insert(now);
                    buffered -= per_step_batches;
                    accel_busy_until = now + step_time;
                    accel_busy_total += step_time;
                    queue.push(Reverse(Event { time: accel_busy_until, kind: EventKind::StepDone }));
                }
            }
            EventKind::StepDone => {
                steps_done += 1;
                // Space freed: parked workers deliver their held batch
                // immediately (worker-side prefetch), then resume
                // producing.
                while buffered < cfg.buffer_cap as u64 {
                    match stalled.pop() {
                        Some(w) => {
                            buffered += 1;
                            batches_produced += 1;
                            queue.push(Reverse(Event {
                                time: now + batch_time,
                                kind: EventKind::BatchReady(w),
                            }));
                        }
                        None => break,
                    }
                }
                if buffered >= per_step_batches {
                    buffered -= per_step_batches;
                    accel_busy_until = now + step_time;
                    accel_busy_total += step_time;
                    queue.push(Reverse(Event { time: accel_busy_until, kind: EventKind::StepDone }));
                } else {
                    accel_idle_since = Some(now);
                }
            }
        }
    }

    let t0 = first_step_start.unwrap_or(0.0);
    let wall = (now - t0).max(1e-9);
    JobSimResult {
        throughput_bps: (steps_done * per_step_batches) as f64 / wall,
        accel_utilization: accel_busy_total / wall,
        accel_stall_fraction: stall_total / wall,
        worker_utilization: ((batches_produced as f64 * batch_time)
            / (now.max(1e-9) * producers as f64))
            .min(1.0),
        sim_seconds: wall,
    }
}

/// Convenience: speedup of `n_workers` disaggregated vs colocated.
pub fn speedup_vs_colocated(model: &ModelSpec, n_workers: usize, cfg_base: &JobSimConfig) -> f64 {
    let colo = simulate_job(model, &JobSimConfig { n_workers: 0, ..cfg_base.clone() });
    let dis = simulate_job(model, &JobSimConfig { n_workers, ..cfg_base.clone() });
    dis.throughput_bps / colo.throughput_bps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::models::model;

    #[test]
    fn colocated_matches_baseline_throughput() {
        let m = model("M1");
        let r = simulate_job(m, &JobSimConfig::default());
        // Colocated M1 must land near the paper's 0.55 b/s.
        assert!((r.throughput_bps - 0.55).abs() / 0.55 < 0.1, "got {}", r.throughput_bps);
        assert!(r.accel_stall_fraction > 0.5, "input-bound => mostly stalled");
    }

    #[test]
    fn paper_worker_counts_reach_service_throughput() {
        // For every scale-out model, deploying the paper's worker count
        // must deliver (approximately) the paper's service throughput.
        for name in ["M1", "M2", "M3", "ResNet50"] {
            let m = model(name);
            let r = simulate_job(
                m,
                &JobSimConfig { n_workers: m.paper_workers, steps: 300, ..Default::default() },
            );
            let rel = (r.throughput_bps - m.service_bps).abs() / m.service_bps;
            assert!(rel < 0.1, "{name}: got {:.2}, paper {:.2}", r.throughput_bps, m.service_bps);
        }
    }

    #[test]
    fn speedups_match_fig8a() {
        for name in ["M1", "M2", "M3", "ResNet50"] {
            let m = model(name);
            let s = speedup_vs_colocated(m, m.paper_workers, &JobSimConfig::default());
            let rel = (s - m.paper_speedup).abs() / m.paper_speedup;
            assert!(rel < 0.12, "{name}: got {s:.1}x, paper {:.1}x", m.paper_speedup);
        }
    }

    #[test]
    fn tiny_worker_pool_underperforms_colocated() {
        // Fig. 9: 8 remote workers are slower than colocated (0.3 vs 0.55
        // b/s) because each remote core also pays RPC/serialization.
        let m = model("M1");
        let r = simulate_job(m, &JobSimConfig { n_workers: 8, ..Default::default() });
        assert!((r.throughput_bps - 0.3).abs() < 0.05, "got {}", r.throughput_bps);
        let s = r.throughput_bps / 0.55;
        assert!(s < 1.0, "8 workers lose to colocated, got {s}x");
    }

    #[test]
    fn m1_sweep_matches_fig9_points() {
        // Fig. 9a anchor points: 16 -> 0.64 b/s, 64 -> 2.3, 128 -> 4.77.
        let m = model("M1");
        for (n, want) in [(16usize, 0.64), (64, 2.3), (128, 4.77)] {
            let r = simulate_job(m, &JobSimConfig { n_workers: n, ..Default::default() });
            let rel = (r.throughput_bps - want).abs() / want;
            assert!(rel < 0.1, "{n} workers: got {:.2}, paper {want}", r.throughput_bps);
        }
    }

    #[test]
    fn throughput_monotone_and_capped() {
        let m = model("M3");
        let mut last = 0.0;
        for n in [4, 16, 64, 128, 512] {
            let r = simulate_job(m, &JobSimConfig { n_workers: n, ..Default::default() });
            assert!(r.throughput_bps >= last - 1e-6, "n={n}");
            last = r.throughput_bps;
        }
        assert!(last <= m.ideal_bps * 1.01);
    }

    #[test]
    fn cross_region_io_bound_colocated_but_hidden_by_scaleout() {
        let m = model("M3");
        // Calibrate per-batch IO so colocated lands ~13.3x below ideal.
        let io = 13.3 / m.ideal_bps;
        let colo = simulate_job(m, &JobSimConfig { io_time_per_batch: io, ..Default::default() });
        let slowdown = m.ideal_bps / colo.throughput_bps;
        assert!(slowdown > 8.0, "colocated out-of-region slowdown {slowdown:.1}");
        // Scale-out hides the latency: many workers fetch in parallel.
        let dis = simulate_job(
            m,
            &JobSimConfig { n_workers: 1024, io_time_per_batch: io, ..Default::default() },
        );
        assert!(dis.throughput_bps > 0.9 * m.ideal_bps, "got {}", dis.throughput_bps);
    }

    #[test]
    fn worker_utilization_falls_with_overprovisioning() {
        let m = model("M3");
        let tight = simulate_job(m, &JobSimConfig { n_workers: 128, ..Default::default() });
        let over = simulate_job(m, &JobSimConfig { n_workers: 640, ..Default::default() });
        assert!(over.worker_utilization < tight.worker_utilization);
        // Throughput unchanged at the plateau (§4.2: over-provisioning
        // costs money, not time).
        assert!((over.throughput_bps - tight.throughput_bps).abs() / tight.throughput_bps < 0.05);
    }

    #[test]
    fn model_bound_jobs_gain_nothing() {
        let m = model("M4");
        let s = speedup_vs_colocated(m, 128, &JobSimConfig::default());
        assert!((s - 1.0).abs() < 0.05, "model-bound job speedup {s}");
    }

    #[test]
    fn m2_falls_short_of_ideal_from_ingest_pressure() {
        let m = model("M2");
        let r = simulate_job(m, &JobSimConfig { n_workers: 1000, ..Default::default() });
        // Even with unlimited workers, ingest caps at service_bps (~8%
        // below ideal) — the §4.2 observation.
        assert!(r.throughput_bps < 0.95 * m.ideal_bps);
        assert!(r.throughput_bps > 0.88 * m.ideal_bps);
    }
}
