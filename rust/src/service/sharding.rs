//! Source-data sharding (§3.3).
//!
//! * OFF — no sharding; every worker's pipeline iterates all shards in a
//!   worker-specific random order (zero-once-or-more visitation).
//! * DYNAMIC — the dispatcher owns a per-job [`SplitTracker`]; workers
//!   pull disjoint splits first-come-first-served. Splits lost with a
//!   failed worker are not redistributed within the epoch (at-most-once).
//! * STATIC — shard indices dealt round-robin across the worker set at
//!   task-creation time.
//!
//! Worker-side, [`DynamicSplitProvider`] adapts the dispatcher's split RPC
//! to the pipeline executor's [`SplitProvider`] interface, and
//! [`ShuffledAllSplits`] provides the OFF-mode random order.

use crate::data::exec::SplitProvider;
use crate::rpc::Pool;
use crate::service::proto::{dispatcher_methods, GetSplitReq, GetSplitResp};
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Dispatcher-side state for DYNAMIC sharding of one job.
///
/// Tracks which worker holds each outstanding split so that a failed
/// worker's in-flight split is recorded as lost (the at-most-once
/// accounting the paper describes).
#[derive(Debug)]
pub struct SplitTracker {
    pending: Mutex<SplitTrackerState>,
}

#[derive(Debug)]
struct SplitTrackerState {
    queue: Vec<u64>,
    /// split -> worker currently processing it.
    assigned: HashMap<u64, u64>,
    /// splits irrecoverably lost to worker failures this epoch.
    lost: Vec<u64>,
    /// splits fully processed (worker finished or returned for more).
    completed: Vec<u64>,
}

impl SplitTracker {
    /// A tracker over `num_shards` splits, handed out in a shuffled order
    /// (`seed`-deterministic) for load balancing.
    pub fn new(num_shards: usize, seed: u64) -> SplitTracker {
        let mut queue: Vec<u64> = (0..num_shards as u64).collect();
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut queue);
        queue.reverse(); // pop from the back
        SplitTracker {
            pending: Mutex::new(SplitTrackerState {
                queue,
                assigned: HashMap::new(),
                lost: Vec::new(),
                completed: Vec::new(),
            }),
        }
    }

    /// Hand the next split to `worker`. Completes the worker's previous
    /// split, if any (a worker asks for a new split only after finishing
    /// the previous one).
    pub fn next_split(&self, worker: u64) -> Option<u64> {
        let mut st = self.pending.lock().unwrap();
        // Worker finished whatever it held.
        let finished: Vec<u64> = st
            .assigned
            .iter()
            .filter(|&(_, &w)| w == worker)
            .map(|(&s, _)| s)
            .collect();
        for s in finished {
            st.assigned.remove(&s);
            st.completed.push(s);
        }
        match st.queue.pop() {
            Some(split) => {
                st.assigned.insert(split, worker);
                Some(split)
            }
            None => None,
        }
    }

    /// Mark a worker dead: its in-flight splits are lost for this epoch
    /// (at-most-once visitation; §3.4 worker fault tolerance).
    pub fn worker_failed(&self, worker: u64) -> Vec<u64> {
        let mut st = self.pending.lock().unwrap();
        let lost: Vec<u64> = st
            .assigned
            .iter()
            .filter(|&(_, &w)| w == worker)
            .map(|(&s, _)| s)
            .collect();
        for s in &lost {
            st.assigned.remove(s);
            st.lost.push(*s);
        }
        lost
    }

    /// Splits not yet handed out.
    pub fn remaining(&self) -> usize {
        self.pending.lock().unwrap().queue.len()
    }

    /// Splits lost to failures.
    pub fn lost(&self) -> Vec<u64> {
        self.pending.lock().unwrap().lost.clone()
    }

    pub fn completed(&self) -> Vec<u64> {
        self.pending.lock().unwrap().completed.clone()
    }

    /// Epoch exhausted: nothing queued or in flight.
    pub fn exhausted(&self) -> bool {
        let st = self.pending.lock().unwrap();
        st.queue.is_empty() && st.assigned.is_empty()
    }
}

/// Deal `num_shards` shards round-robin across `num_workers` workers;
/// returns per-worker shard lists (STATIC policy).
pub fn static_assignment(num_shards: usize, num_workers: usize) -> Vec<Vec<u64>> {
    let mut out = vec![Vec::new(); num_workers.max(1)];
    for s in 0..num_shards as u64 {
        out[(s as usize) % num_workers.max(1)].push(s);
    }
    out
}

/// OFF-mode provider: all shards, in a worker-specific shuffled order that
/// reshuffles each epoch.
pub struct ShuffledAllSplits {
    n: usize,
    state: Mutex<(Vec<usize>, usize, Rng)>,
}

impl ShuffledAllSplits {
    pub fn new(n: usize, seed: u64) -> Arc<ShuffledAllSplits> {
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        Arc::new(ShuffledAllSplits { n, state: Mutex::new((order, 0, rng)) })
    }
}

impl SplitProvider for ShuffledAllSplits {
    fn next_split(&self) -> Option<usize> {
        let mut st = self.state.lock().unwrap();
        if st.1 >= st.0.len() {
            return None;
        }
        let v = st.0[st.1];
        st.1 += 1;
        Some(v)
    }

    fn reset(&self) {
        let mut st = self.state.lock().unwrap();
        let (order, pos, rng) = &mut *st;
        rng.shuffle(order);
        *pos = 0;
        let _ = self.n;
    }
}

/// Worker-side DYNAMIC provider: pulls splits from the dispatcher over
/// RPC. `reset` is a no-op — the dispatcher owns epoch boundaries.
pub struct DynamicSplitProvider {
    pool: Arc<Pool>,
    dispatcher_addr: String,
    job_id: u64,
    worker_id: u64,
    deadline: Duration,
    /// Count of splits obtained (metrics / tests).
    pub splits_obtained: AtomicUsize,
}

impl DynamicSplitProvider {
    pub fn new(pool: Arc<Pool>, dispatcher_addr: String, job_id: u64, worker_id: u64) -> Arc<Self> {
        Arc::new(DynamicSplitProvider {
            pool,
            dispatcher_addr,
            job_id,
            worker_id,
            deadline: Duration::from_secs(10),
            splits_obtained: AtomicUsize::new(0),
        })
    }
}

impl SplitProvider for DynamicSplitProvider {
    fn next_split(&self) -> Option<usize> {
        let req = GetSplitReq { job_id: self.job_id, worker_id: self.worker_id };
        let resp: GetSplitResp = crate::rpc::call_typed(
            &self.pool,
            &self.dispatcher_addr,
            dispatcher_methods::GET_SPLIT,
            &req,
            self.deadline,
        )
        .ok()?;
        let s = resp.split?;
        self.splits_obtained.fetch_add(1, Ordering::Relaxed);
        Some(s as usize)
    }

    fn reset(&self) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn dynamic_splits_are_disjoint_and_complete() {
        let t = SplitTracker::new(20, 7);
        let mut seen = HashSet::new();
        // Two workers pulling interleaved.
        loop {
            let a = t.next_split(1);
            let b = t.next_split(2);
            for s in [a, b].into_iter().flatten() {
                assert!(seen.insert(s), "split {s} handed out twice");
            }
            if a.is_none() && b.is_none() {
                break;
            }
        }
        assert_eq!(seen.len(), 20);
        assert!(t.exhausted());
    }

    #[test]
    fn shuffled_handout_differs_from_sequential() {
        let t = SplitTracker::new(32, 99);
        let mut order = Vec::new();
        while let Some(s) = t.next_split(1) {
            order.push(s);
        }
        assert_ne!(order, (0..32).collect::<Vec<u64>>());
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..32).collect::<Vec<u64>>());
    }

    #[test]
    fn worker_failure_loses_in_flight_split_only() {
        let t = SplitTracker::new(4, 1);
        let s1 = t.next_split(1).unwrap();
        let _s2 = t.next_split(2).unwrap();
        let lost = t.worker_failed(1);
        assert_eq!(lost, vec![s1]);
        assert_eq!(t.lost(), vec![s1]);
        // Remaining splits still served; lost split never reappears.
        let mut rest = Vec::new();
        while let Some(s) = t.next_split(2) {
            rest.push(s);
        }
        assert!(!rest.contains(&s1));
        assert!(t.exhausted());
        // at-most-once: completed + lost + in-flight(0) == total
        assert_eq!(t.completed().len() + t.lost().len(), 4);
    }

    #[test]
    fn next_split_completes_previous() {
        let t = SplitTracker::new(3, 5);
        let a = t.next_split(7).unwrap();
        assert!(t.completed().is_empty());
        let _b = t.next_split(7).unwrap();
        assert_eq!(t.completed(), vec![a]);
    }

    #[test]
    fn static_assignment_partitions() {
        let a = static_assignment(10, 3);
        assert_eq!(a.len(), 3);
        let mut all: Vec<u64> = a.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<u64>>());
        // Balanced within 1.
        let lens: Vec<usize> = a.iter().map(|v| v.len()).collect();
        assert!(lens.iter().max().unwrap() - lens.iter().min().unwrap() <= 1);
    }

    #[test]
    fn static_assignment_zero_workers_safe() {
        let a = static_assignment(3, 0);
        assert_eq!(a.len(), 1);
        assert_eq!(a[0], vec![0, 1, 2]);
    }

    #[test]
    fn shuffled_all_splits_reshuffles_per_epoch() {
        let p = ShuffledAllSplits::new(16, 3);
        let mut e1 = Vec::new();
        while let Some(s) = p.next_split() {
            e1.push(s);
        }
        p.reset();
        let mut e2 = Vec::new();
        while let Some(s) = p.next_split() {
            e2.push(s);
        }
        assert_eq!(e1.len(), 16);
        assert_eq!(e2.len(), 16);
        assert_ne!(e1, e2, "epochs should reshuffle");
        let mut s1 = e1.clone();
        s1.sort_unstable();
        assert_eq!(s1, (0..16).collect::<Vec<usize>>());
    }
}
