"""L2 model correctness: shapes, determinism, and trainability.

The train step must actually learn (loss decreases on a repeated batch) —
this is the same computation the Rust e2e example drives through PJRT, so
if it learns here it learns there (identical HLO).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model

jax.config.update("jax_platforms", "cpu")

CFG = model.ModelConfig(
    vocab=256, d_model=32, n_layers=1, n_heads=2, d_ff=64, seq_len=16, batch=4
)


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, seed=0)


def test_param_shapes_match_declared(params):
    declared = model.param_shapes(CFG)
    assert len(params) == len(declared)
    for p, (name, shape) in zip(params, declared):
        assert p.shape == shape, name


def test_param_count_consistent(params):
    assert model.param_count(CFG) == sum(int(np.prod(p.shape)) for p in params)


def test_init_is_deterministic():
    a = model.init_params(CFG, seed=0)
    b = model.init_params(CFG, seed=0)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_init_seed_changes_weights():
    a = model.init_params(CFG, seed=0)
    b = model.init_params(CFG, seed=1)
    assert any(not np.allclose(x, y) for x, y in zip(a, b))


def test_forward_shapes(params):
    toks = jnp.zeros((CFG.batch, CFG.seq_len), jnp.int32)
    logits = model.forward(params, toks, CFG)
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)


def test_loss_is_near_uniform_at_init(params):
    rng = np.random.default_rng(0)
    toks = rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len + 1)).astype(np.int32)
    loss = model.loss_fn(params, jnp.asarray(toks), CFG)
    # CE of a near-uniform predictor over 256 classes is ~ln(256) = 5.55.
    assert 4.5 < float(loss) < 6.5


def test_causality_future_tokens_do_not_affect_logits(params):
    rng = np.random.default_rng(1)
    a = rng.integers(0, 256, (1, CFG.seq_len)).astype(np.int32)
    b = a.copy()
    b[0, -1] = (b[0, -1] + 17) % 256  # change only the last input token
    la = model.forward(params, jnp.asarray(a), CFG)
    lb = model.forward(params, jnp.asarray(b), CFG)
    # All positions before the changed one are unchanged.
    np.testing.assert_allclose(la[0, :-1], lb[0, :-1], rtol=1e-4, atol=1e-5)


def test_train_step_reduces_loss_on_fixed_batch(params):
    rng = np.random.default_rng(2)
    toks = jnp.asarray(
        rng.integers(0, 64, (CFG.batch, CFG.seq_len + 1)).astype(np.int32)
    )
    step = jax.jit(lambda p, t: model.train_step(p, t, jnp.float32(0.1), CFG))
    p = params
    first = float(model.loss_fn(p, toks, CFG))
    for _ in range(30):
        out = step(p, toks)
        p, loss = out[:-1], out[-1]
    assert float(loss) < first * 0.7, (first, float(loss))


def test_train_step_returns_all_params_plus_loss(params):
    toks = jnp.zeros((CFG.batch, CFG.seq_len + 1), jnp.int32)
    out = model.train_step(params, toks, jnp.float32(0.01), CFG)
    assert len(out) == len(params) + 1
    assert out[-1].shape == ()


def test_train_step_zero_lr_is_identity(params):
    toks = jnp.zeros((CFG.batch, CFG.seq_len + 1), jnp.int32)
    out = model.train_step(params, toks, jnp.float32(0.0), CFG)
    for p, q in zip(params, out[:-1]):
        np.testing.assert_array_equal(p, q)


def test_preprocess_nlp_mask_and_lengths():
    toks = np.array([[3, 5, 0, 0], [1, 2, 3, 4]], np.uint32)
    out_toks, mask, lengths = model.preprocess_nlp(jnp.asarray(toks))
    np.testing.assert_array_equal(np.asarray(lengths), [2, 4])
    np.testing.assert_array_equal(np.asarray(mask), [[1, 1, 0, 0], [1, 1, 1, 1]])
    assert out_toks.dtype == jnp.int32


def test_preprocess_vision_matches_kernel_oracle():
    from compile.kernels import ref as kref

    rng = np.random.default_rng(3)
    img = rng.integers(0, 256, (4, 8, 8, 3), dtype=np.uint8)
    flip = np.array([0, 1, 0, 1], np.float32)
    br = np.zeros(4, np.float32)
    ct = np.ones(4, np.float32)
    got = model.preprocess_vision(img, flip, br, ct)
    want = kref.augment_ref(jnp.asarray(img), jnp.asarray(flip), jnp.asarray(br), jnp.asarray(ct))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_aot_entries_cover_all_artifacts():
    entries = model.aot_entries(CFG)
    assert set(entries) == {
        "params_init",
        "train_step",
        "eval_loss",
        "preprocess_vision",
        "preprocess_nlp",
    }
