"""L2: JAX compute graphs AOT-compiled for the Rust runtime.

Three graphs, each lowered once by aot.py to HLO text and executed from
Rust via PJRT (python never runs on the request path):

  * preprocess_vision — the vision map-fn the service's *workers* run on
    every batch. Calls the L1 fused augmentation Pallas kernel.
  * preprocess_nlp    — the NLP featurization map-fn (clip + padding mask +
    length stats) workers run for sequence workloads.
  * train_step        — byte-level transformer-LM forward + backward + SGD,
    the ML computation the service's *clients* (accelerator hosts) run.
    The position-wise FFN is the L1 fused Pallas kernel via its custom-vjp
    wrapper.
  * params_init       — deterministic parameter initialization, so Rust can
    bootstrap training without any Python at runtime.

Scale substitution (DESIGN.md §2): the paper trains production models on
TPU v4 pods; our e2e example must train for a few hundred steps on one CPU
core, so the default config is a ~1.7M-parameter byte-level LM. The
architecture (pre-LN transformer, causal MHA, tied embeddings) matches the
shape of the paper's NLP workloads; the accelerator *demand rate* used in
experiments is modeled separately (sim/models.rs), calibrated to the
paper's reported batches/s.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import augment as augment_kernel
from .kernels import ffn as ffn_kernel


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Byte-level transformer LM hyperparameters."""

    vocab: int = 256
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 512
    seq_len: int = 64
    batch: int = 8

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


DEFAULT_CONFIG = ModelConfig()

# Fixed preprocessing shapes for the AOT artifacts (workers feed batches of
# exactly these shapes; the Rust pipeline pads/crops to match).
VISION_BATCH = 32
VISION_HW = 32
VISION_C = 3
NLP_BATCH = 32
NLP_SEQ = 128


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_shapes(cfg: ModelConfig):
    """Ordered (name, shape) list — the flat calling convention shared with
    Rust. The manifest (aot.py) serializes this so the Rust runtime knows
    how to slot literals into train_step."""
    shapes = [
        ("embed", (cfg.vocab, cfg.d_model)),
        ("pos", (cfg.seq_len, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        p = f"l{i}_"
        shapes += [
            (p + "ln1_g", (cfg.d_model,)),
            (p + "ln1_b", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.d_model)),
            (p + "wk", (cfg.d_model, cfg.d_model)),
            (p + "wv", (cfg.d_model, cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2_g", (cfg.d_model,)),
            (p + "ln2_b", (cfg.d_model,)),
            (p + "w1", (cfg.d_model, cfg.d_ff)),
            (p + "b1", (cfg.d_ff,)),
            (p + "w2", (cfg.d_ff, cfg.d_model)),
            (p + "b2", (cfg.d_model,)),
        ]
    shapes += [("lnf_g", (cfg.d_model,)), ("lnf_b", (cfg.d_model,))]
    return shapes


def param_count(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_shapes(cfg))


def init_params(cfg: ModelConfig, seed: int = 0):
    """Deterministic init; returns the flat tuple of arrays."""
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("_b", "b1", "b2")):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            scale = 0.02 if name in ("embed", "pos") else 1.0 / jnp.sqrt(fan_in)
            out.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return tuple(out)


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------


def _layer_norm(x, g, b, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * g + b


def _attention(x, wq, wk, wv, wo, cfg: ModelConfig):
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim

    def split(t):
        return t.reshape(b, s, h, hd).transpose(0, 2, 1, 3)  # (b,h,s,hd)

    q, k, v = split(x @ wq), split(x @ wk), split(x @ wv)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(hd).astype(x.dtype)
    causal = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(causal, scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, d)
    return ctx @ wo


def forward(params, tokens, cfg: ModelConfig):
    """Logits for next-token prediction. tokens: (B, S) int32."""
    it = iter(params)
    embed, pos = next(it), next(it)
    x = embed[tokens] + pos[None, : tokens.shape[1]]
    for _ in range(cfg.n_layers):
        ln1_g, ln1_b = next(it), next(it)
        wq, wk, wv, wo = next(it), next(it), next(it), next(it)
        ln2_g, ln2_b = next(it), next(it)
        w1, b1, w2, b2 = next(it), next(it), next(it), next(it)
        x = x + _attention(_layer_norm(x, ln1_g, ln1_b), wq, wk, wv, wo, cfg)
        h = _layer_norm(x, ln2_g, ln2_b)
        b, s, d = h.shape
        # L1 fused FFN kernel over (B*S, D) rows; custom-vjp so the train
        # step's backward pass lowers into the same artifact.
        hf = ffn_kernel.ffn_trainable(
            h.reshape(b * s, d), w1, b1, w2, b2
        ).reshape(b, s, d)
        x = x + hf
    lnf_g, lnf_b = next(it), next(it)
    x = _layer_norm(x, lnf_g, lnf_b)
    return x @ embed.T  # tied unembedding


def loss_fn(params, tokens_io, cfg: ModelConfig):
    """Mean next-token cross-entropy. tokens_io: (B, S+1) int32."""
    inputs, targets = tokens_io[:, :-1], tokens_io[:, 1:]
    logits = forward(params, inputs, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def train_step(params, tokens_io, lr, cfg: ModelConfig):
    """One SGD step. Returns (new_params..., loss) as a flat tuple."""
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens_io, cfg)
    new_params = tuple(p - lr * g for p, g in zip(params, grads))
    return new_params + (loss,)


# ---------------------------------------------------------------------------
# Preprocessing graphs (worker-side map fns)
# ---------------------------------------------------------------------------


def preprocess_vision(images_u8, flip, brightness, contrast):
    """Vision worker map-fn: fused augmentation via the L1 Pallas kernel.

    images_u8: (B, H, W, C) uint8; per-sample params (B,) float32.
    Returns (B, H, W, C) float32.
    """
    return augment_kernel.augment(images_u8, flip, brightness, contrast)


def preprocess_nlp(tokens_u32):
    """NLP worker map-fn: clip to vocab, padding mask, unpadded lengths.

    tokens_u32: (B, S) uint32, 0 = PAD.
    Returns (tokens_i32 (B,S), mask_f32 (B,S), lengths_i32 (B,)).
    """
    toks = jnp.clip(tokens_u32.astype(jnp.int32), 0, 255)
    mask = (toks > 0).astype(jnp.float32)
    lengths = jnp.sum(toks > 0, axis=-1).astype(jnp.int32)
    return toks, mask, lengths


# ---------------------------------------------------------------------------
# Jitted entry points for AOT lowering (fixed shapes)
# ---------------------------------------------------------------------------


def aot_entries(cfg: ModelConfig = DEFAULT_CONFIG):
    """Returns {artifact_name: (jitted_fn, example_args)} for aot.py."""
    shapes = param_shapes(cfg)
    params_spec = tuple(
        jax.ShapeDtypeStruct(s, jnp.float32) for _, s in shapes
    )
    tokens_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32)
    lr_spec = jax.ShapeDtypeStruct((), jnp.float32)

    def train_step_flat(*args):
        params = args[: len(shapes)]
        tokens_io, lr = args[len(shapes)], args[len(shapes) + 1]
        return train_step(params, tokens_io, lr, cfg)

    def loss_flat(*args):
        params = args[: len(shapes)]
        tokens_io = args[len(shapes)]
        return (loss_fn(params, tokens_io, cfg),)

    def params_init_fn():
        return init_params(cfg, seed=0)

    vis_spec = (
        jax.ShapeDtypeStruct((VISION_BATCH, VISION_HW, VISION_HW, VISION_C), jnp.uint8),
        jax.ShapeDtypeStruct((VISION_BATCH,), jnp.float32),
        jax.ShapeDtypeStruct((VISION_BATCH,), jnp.float32),
        jax.ShapeDtypeStruct((VISION_BATCH,), jnp.float32),
    )
    nlp_spec = (jax.ShapeDtypeStruct((NLP_BATCH, NLP_SEQ), jnp.uint32),)

    return {
        "params_init": (jax.jit(params_init_fn), ()),
        "train_step": (
            jax.jit(train_step_flat),
            params_spec + (tokens_spec, lr_spec),
        ),
        "eval_loss": (jax.jit(loss_flat), params_spec + (tokens_spec,)),
        "preprocess_vision": (
            jax.jit(lambda *a: (preprocess_vision(*a),)),
            vis_spec,
        ),
        "preprocess_nlp": (jax.jit(preprocess_nlp), nlp_spec),
    }
