//! Storage substrate: the Colossus/GCS stand-in.
//!
//! The paper's workers all read source data from a shared distributed
//! store (Colossus internally, GCS for the open-source experiments), and
//! one experiment (§4.2 "Cross-region Scenario") depends on the store
//! being in a *different region* than preprocessing and training. We
//! reproduce both properties:
//!
//! * [`ObjectStore`] — a process-wide object store shared by all workers,
//!   with an explicit region + network model ([`NetModel`]) that injects
//!   per-read latency and bandwidth delays when the reader's region
//!   differs from the store's.
//! * [`record`] — a TFRecord-like CRC-framed record file format; datasets
//!   are directories of sharded record files, one file per source shard
//!   (matching §3.3 "each file constitutes a source data shard").
//! * [`dataset`] — synthetic dataset generators (images, token sequences)
//!   standing in for COCO/ImageNet and the production NLP corpora.

pub mod dataset;
pub mod record;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Geographical region tag. Cheap to clone and compare.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Region(pub String);

impl Region {
    pub fn new(name: &str) -> Region {
        Region(name.to_string())
    }
}

impl std::fmt::Display for Region {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

/// Network model between a reader and the store. Latencies are per
/// request; bandwidth converts object size into transfer time.
#[derive(Debug, Clone)]
pub struct NetModel {
    /// Round-trip latency when reader and store share a region.
    pub same_region_latency: Duration,
    /// Round-trip latency when they do not (paper: different continent).
    pub cross_region_latency: Duration,
    /// Reader-observed bandwidth within a region (bytes/second).
    pub same_region_bw: f64,
    /// Reader-observed bandwidth across regions.
    pub cross_region_bw: f64,
    /// When false, delays are computed (for the simulator / accounting)
    /// but not slept, keeping unit tests fast.
    pub inject_delays: bool,
}

impl Default for NetModel {
    fn default() -> Self {
        // Same-region numbers loosely follow intra-zone GCP: sub-ms RTT,
        // multi-GB/s effective throughput. Cross-region follows the
        // paper's "different continent": ~150 ms RTT, constrained BW.
        NetModel {
            same_region_latency: Duration::from_micros(500),
            cross_region_latency: Duration::from_millis(150),
            same_region_bw: 2e9,
            cross_region_bw: 50e6,
            inject_delays: false,
        }
    }
}

impl NetModel {
    /// Transfer delay for `bytes` read by `reader` from a store in
    /// `store_region`.
    pub fn read_delay(&self, reader: &Region, store_region: &Region, bytes: usize) -> Duration {
        let (lat, bw) = if reader == store_region {
            (self.same_region_latency, self.same_region_bw)
        } else {
            (self.cross_region_latency, self.cross_region_bw)
        };
        lat + Duration::from_secs_f64(bytes as f64 / bw)
    }
}

/// Errors from the storage layer.
#[derive(Debug)]
pub enum StorageError {
    NotFound(String),
    Corrupt(String),
    Io(std::io::Error),
}

impl std::fmt::Display for StorageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StorageError::NotFound(key) => write!(f, "object not found: {key}"),
            StorageError::Corrupt(msg) => write!(f, "record corrupt: {msg}"),
            StorageError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for StorageError {}

impl From<std::io::Error> for StorageError {
    fn from(e: std::io::Error) -> StorageError {
        StorageError::Io(e)
    }
}

pub type StorageResult<T> = Result<T, StorageError>;

/// Cumulative read-side statistics, used by the Fig-10 "bytes read from
/// storage stays constant with sharing" analysis.
#[derive(Debug, Default)]
pub struct StoreStats {
    pub reads: AtomicU64,
    pub bytes_read: AtomicU64,
    pub cross_region_reads: AtomicU64,
    pub simulated_delay_us: AtomicU64,
}

/// Shared in-process object store with region-aware read costs.
///
/// Keys are `/`-separated paths; `list` is prefix-ordered (BTreeMap), so
/// shard enumeration is deterministic.
pub struct ObjectStore {
    region: Region,
    net: NetModel,
    objects: Mutex<BTreeMap<String, Arc<Vec<u8>>>>,
    pub stats: StoreStats,
}

impl ObjectStore {
    pub fn new(region: Region, net: NetModel) -> Arc<ObjectStore> {
        Arc::new(ObjectStore {
            region,
            net,
            objects: Mutex::new(BTreeMap::new()),
            stats: StoreStats::default(),
        })
    }

    /// In-region store with no injected delays: the default for tests.
    pub fn in_memory() -> Arc<ObjectStore> {
        Self::new(Region::new("local"), NetModel::default())
    }

    pub fn region(&self) -> &Region {
        &self.region
    }

    pub fn put(&self, key: &str, bytes: Vec<u8>) {
        self.objects.lock().unwrap().insert(key.to_string(), Arc::new(bytes));
    }

    /// Append `bytes` to the object at `key` (creating it when absent)
    /// and return the byte offset the appended chunk starts at. The
    /// spill tier appends encoded segments to one data object per job
    /// and addresses them by `(offset, len)` via [`ObjectStore::read_range_from`].
    /// Copy-on-write against concurrent readers: an `Arc` handed out by
    /// a previous read keeps observing the pre-append bytes.
    pub fn append(&self, key: &str, bytes: &[u8]) -> u64 {
        let mut objects = self.objects.lock().unwrap();
        let entry = objects.entry(key.to_string()).or_insert_with(|| Arc::new(Vec::new()));
        let buf = Arc::make_mut(entry);
        let offset = buf.len() as u64;
        buf.extend_from_slice(bytes);
        offset
    }

    pub fn delete(&self, key: &str) -> bool {
        self.objects.lock().unwrap().remove(key).is_some()
    }

    pub fn contains(&self, key: &str) -> bool {
        self.objects.lock().unwrap().contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.objects.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total bytes stored (capacity accounting).
    pub fn stored_bytes(&self) -> usize {
        self.objects.lock().unwrap().values().map(|v| v.len()).sum()
    }

    /// Read an object from `reader_region`, paying the modeled network
    /// cost. `Arc` return avoids copying multi-MB shards per read.
    pub fn get_from(&self, reader_region: &Region, key: &str) -> StorageResult<Arc<Vec<u8>>> {
        let obj = self
            .objects
            .lock()
            .unwrap()
            .get(key)
            .cloned()
            .ok_or_else(|| StorageError::NotFound(key.to_string()))?;
        self.stats.reads.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_read.fetch_add(obj.len() as u64, Ordering::Relaxed);
        if reader_region != &self.region {
            self.stats.cross_region_reads.fetch_add(1, Ordering::Relaxed);
        }
        let delay = self.net.read_delay(reader_region, &self.region, obj.len());
        self.stats
            .simulated_delay_us
            .fetch_add(delay.as_micros() as u64, Ordering::Relaxed);
        if self.net.inject_delays {
            std::thread::sleep(delay);
        }
        Ok(obj)
    }

    /// Read `len` bytes at `offset` within the object at `key`, paying
    /// the modeled network cost for the *range* (not the whole object):
    /// the spill tier stores many segments in one data object and reads
    /// them back individually.
    pub fn read_range_from(
        &self,
        reader_region: &Region,
        key: &str,
        offset: u64,
        len: u64,
    ) -> StorageResult<Vec<u8>> {
        let obj = self
            .objects
            .lock()
            .unwrap()
            .get(key)
            .cloned()
            .ok_or_else(|| StorageError::NotFound(key.to_string()))?;
        let (off, len) = (offset as usize, len as usize);
        let end = off.checked_add(len).filter(|&e| e <= obj.len()).ok_or_else(|| {
            StorageError::Corrupt(format!(
                "range {off}+{len} past end of {key} ({} bytes)",
                obj.len()
            ))
        })?;
        self.stats.reads.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_read.fetch_add(len as u64, Ordering::Relaxed);
        if reader_region != &self.region {
            self.stats.cross_region_reads.fetch_add(1, Ordering::Relaxed);
        }
        let delay = self.net.read_delay(reader_region, &self.region, len);
        self.stats
            .simulated_delay_us
            .fetch_add(delay.as_micros() as u64, Ordering::Relaxed);
        if self.net.inject_delays {
            std::thread::sleep(delay);
        }
        Ok(obj[off..end].to_vec())
    }

    /// Convenience in-region read.
    pub fn get(&self, key: &str) -> StorageResult<Arc<Vec<u8>>> {
        let region = self.region.clone();
        self.get_from(&region, key)
    }

    /// Keys with the given prefix, in lexicographic order.
    pub fn list(&self, prefix: &str) -> Vec<String> {
        self.objects
            .lock()
            .unwrap()
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, _)| k.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let s = ObjectStore::in_memory();
        s.put("a/b", vec![1, 2, 3]);
        assert_eq!(*s.get("a/b").unwrap(), vec![1, 2, 3]);
        assert!(matches!(s.get("missing"), Err(StorageError::NotFound(_))));
    }

    #[test]
    fn list_prefix_ordered() {
        let s = ObjectStore::in_memory();
        for k in ["ds/shard-002", "ds/shard-000", "other/x", "ds/shard-001"] {
            s.put(k, vec![]);
        }
        assert_eq!(
            s.list("ds/"),
            vec!["ds/shard-000", "ds/shard-001", "ds/shard-002"]
        );
        assert_eq!(s.list("nope/"), Vec::<String>::new());
    }

    #[test]
    fn delete_and_len() {
        let s = ObjectStore::in_memory();
        s.put("k", vec![0; 10]);
        assert_eq!(s.len(), 1);
        assert_eq!(s.stored_bytes(), 10);
        assert!(s.delete("k"));
        assert!(!s.delete("k"));
        assert!(s.is_empty());
    }

    #[test]
    fn append_returns_offsets_and_ranges_read_back() {
        let s = ObjectStore::in_memory();
        assert_eq!(s.append("seg", b"abcd"), 0);
        assert_eq!(s.append("seg", b"efg"), 4);
        assert_eq!(s.read_range_from(s.region(), "seg", 0, 4).unwrap(), b"abcd");
        assert_eq!(s.read_range_from(s.region(), "seg", 4, 3).unwrap(), b"efg");
        assert!(matches!(
            s.read_range_from(s.region(), "seg", 5, 3),
            Err(StorageError::Corrupt(_))
        ));
        assert!(matches!(
            s.read_range_from(s.region(), "nope", 0, 1),
            Err(StorageError::NotFound(_))
        ));
    }

    #[test]
    fn append_preserves_prior_readers() {
        let s = ObjectStore::in_memory();
        s.append("seg", b"old");
        let snapshot = s.get("seg").unwrap();
        s.append("seg", b"new");
        assert_eq!(&*snapshot, b"old");
        assert_eq!(&*s.get("seg").unwrap(), b"oldnew");
    }

    #[test]
    fn range_read_charges_range_bytes_only() {
        let s = ObjectStore::new(Region::new("us"), NetModel::default());
        s.put("k", vec![0; 1000]);
        s.read_range_from(&Region::new("eu"), "k", 100, 10).unwrap();
        assert_eq!(s.stats.bytes_read.load(Ordering::Relaxed), 10);
        assert_eq!(s.stats.cross_region_reads.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn read_stats_accumulate() {
        let s = ObjectStore::in_memory();
        s.put("k", vec![0; 100]);
        s.get("k").unwrap();
        s.get("k").unwrap();
        assert_eq!(s.stats.reads.load(Ordering::Relaxed), 2);
        assert_eq!(s.stats.bytes_read.load(Ordering::Relaxed), 200);
        assert_eq!(s.stats.cross_region_reads.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn cross_region_costs_more() {
        let net = NetModel::default();
        let us = Region::new("us-central1");
        let eu = Region::new("europe-west4");
        let near = net.read_delay(&us, &us, 1 << 20);
        let far = net.read_delay(&eu, &us, 1 << 20);
        assert!(far > near * 10, "near={near:?} far={far:?}");
    }

    #[test]
    fn cross_region_read_counted() {
        let s = ObjectStore::new(Region::new("us"), NetModel::default());
        s.put("k", vec![0; 8]);
        s.get_from(&Region::new("eu"), "k").unwrap();
        assert_eq!(s.stats.cross_region_reads.load(Ordering::Relaxed), 1);
        assert!(s.stats.simulated_delay_us.load(Ordering::Relaxed) >= 150_000);
    }

    #[test]
    fn concurrent_readers() {
        let s = ObjectStore::in_memory();
        s.put("k", (0..=255u8).collect());
        let mut hs = vec![];
        for _ in 0..8 {
            let s2 = s.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    assert_eq!(s2.get("k").unwrap().len(), 256);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(s.stats.reads.load(Ordering::Relaxed), 800);
    }
}
