//! Data-visitation-guarantee verification (§2, §3.3).
//!
//! The paper's central relaxation is trading exactly-once visitation for
//! at-most-once (dynamic sharding under failures) or zero-once-or-more
//! (no sharding). Tests and benches feed every consumed element's source
//! ids into a [`VisitationTracker`] and then assert the guarantee the
//! active sharding policy promises.

use std::collections::HashMap;

/// Which guarantee to check.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Guarantee {
    /// Every sample seen exactly once.
    ExactlyOnce,
    /// No sample seen more than once; misses allowed.
    AtMostOnce,
    /// Anything goes (OFF sharding).
    ZeroOnceOrMore,
}

/// Accumulates observed sample ids for one epoch.
#[derive(Debug, Default)]
pub struct VisitationTracker {
    counts: HashMap<u64, u64>,
    total_observations: u64,
}

/// Verification outcome with enough detail to debug a violation.
#[derive(Debug, Clone, PartialEq)]
pub struct VisitationReport {
    pub guarantee: Guarantee,
    pub ok: bool,
    pub unique_seen: usize,
    pub duplicates: Vec<u64>,
    pub missing: Vec<u64>,
    pub total_observations: u64,
}

impl VisitationTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one element's contributing sample ids.
    pub fn observe(&mut self, ids: &[u64]) {
        for &id in ids {
            *self.counts.entry(id).or_insert(0) += 1;
            self.total_observations += 1;
        }
    }

    pub fn seen(&self, id: u64) -> u64 {
        self.counts.get(&id).copied().unwrap_or(0)
    }

    pub fn unique_seen(&self) -> usize {
        self.counts.len()
    }

    /// Verify `guarantee` against the universe `0..total_samples`.
    pub fn verify(&self, guarantee: Guarantee, total_samples: u64) -> VisitationReport {
        let mut duplicates: Vec<u64> =
            self.counts.iter().filter(|&(_, &c)| c > 1).map(|(&id, _)| id).collect();
        duplicates.sort_unstable();
        let mut missing: Vec<u64> =
            (0..total_samples).filter(|id| !self.counts.contains_key(id)).collect();
        missing.sort_unstable();
        let extraneous = self.counts.keys().any(|&id| id >= total_samples);

        let ok = match guarantee {
            Guarantee::ExactlyOnce => duplicates.is_empty() && missing.is_empty() && !extraneous,
            Guarantee::AtMostOnce => duplicates.is_empty() && !extraneous,
            Guarantee::ZeroOnceOrMore => !extraneous,
        };
        VisitationReport {
            guarantee,
            ok,
            unique_seen: self.counts.len(),
            duplicates,
            missing,
            total_observations: self.total_observations,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exactly_once_happy_path() {
        let mut t = VisitationTracker::new();
        t.observe(&[0, 1, 2]);
        t.observe(&[3, 4]);
        let r = t.verify(Guarantee::ExactlyOnce, 5);
        assert!(r.ok, "{r:?}");
        assert_eq!(r.unique_seen, 5);
        assert_eq!(r.total_observations, 5);
    }

    #[test]
    fn exactly_once_detects_miss_and_dup() {
        let mut t = VisitationTracker::new();
        t.observe(&[0, 1, 1, 3]);
        let r = t.verify(Guarantee::ExactlyOnce, 4);
        assert!(!r.ok);
        assert_eq!(r.duplicates, vec![1]);
        assert_eq!(r.missing, vec![2]);
    }

    #[test]
    fn at_most_once_allows_misses_only() {
        let mut t = VisitationTracker::new();
        t.observe(&[0, 2]);
        assert!(t.verify(Guarantee::AtMostOnce, 4).ok);
        t.observe(&[2]);
        let r = t.verify(Guarantee::AtMostOnce, 4);
        assert!(!r.ok);
        assert_eq!(r.duplicates, vec![2]);
    }

    #[test]
    fn zero_once_or_more_allows_everything_in_range() {
        let mut t = VisitationTracker::new();
        t.observe(&[0, 0, 0, 1]);
        assert!(t.verify(Guarantee::ZeroOnceOrMore, 2).ok);
    }

    #[test]
    fn out_of_universe_ids_always_fail() {
        let mut t = VisitationTracker::new();
        t.observe(&[99]);
        assert!(!t.verify(Guarantee::ZeroOnceOrMore, 5).ok);
        assert!(!t.verify(Guarantee::AtMostOnce, 5).ok);
    }

    #[test]
    fn seen_counts() {
        let mut t = VisitationTracker::new();
        t.observe(&[7, 7]);
        assert_eq!(t.seen(7), 2);
        assert_eq!(t.seen(8), 0);
    }
}
