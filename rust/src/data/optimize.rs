//! Static graph optimizations (§3.2).
//!
//! Before a client registers a pipeline with the dispatcher, the graph
//! passes through rewrite stages mirroring tf.data's: dead transform
//! elimination, map fusion, and transparent prefetch injection. Rewrites
//! are semantics-preserving: the optimized graph yields the same element
//! sequence (prefetch only overlaps execution; fusion composes UDFs in
//! order).

use super::graph::{GraphDef, Node};

/// Which passes to run; `Default` enables everything.
#[derive(Debug, Clone)]
pub struct OptimizeOptions {
    pub dead_elimination: bool,
    pub map_fusion: bool,
    pub prefetch_injection: bool,
    /// Depth of the injected terminal prefetch buffer.
    pub injected_prefetch_depth: u32,
}

impl Default for OptimizeOptions {
    fn default() -> Self {
        OptimizeOptions {
            dead_elimination: true,
            map_fusion: true,
            prefetch_injection: true,
            injected_prefetch_depth: 2,
        }
    }
}

/// Run all enabled passes until fixpoint, then inject prefetch.
pub fn optimize(graph: &GraphDef, opts: &OptimizeOptions) -> GraphDef {
    let mut nodes = graph.nodes.clone();
    loop {
        let before = nodes.len();
        if opts.dead_elimination {
            nodes = eliminate_dead(nodes);
        }
        if opts.map_fusion {
            nodes = fuse_maps(nodes);
        }
        if nodes.len() == before {
            break;
        }
    }
    if opts.prefetch_injection {
        nodes = inject_prefetch(nodes, opts.injected_prefetch_depth);
    }
    GraphDef { nodes }
}

/// Remove transformations that cannot affect the element stream:
/// `repeat(1)`, `take(u64::MAX)`, `skip(0)`, `shuffle(buffer<=1)`,
/// `prefetch(0)`, `map(identity)`, and `FlatMap` markers.
fn eliminate_dead(nodes: Vec<Node>) -> Vec<Node> {
    nodes
        .into_iter()
        .filter(|n| {
            !matches!(
                n,
                Node::Repeat { n: 1 }
                    | Node::Take { n: u64::MAX }
                    | Node::Skip { n: 0 }
                    | Node::Shuffle { buffer: 0..=1, .. }
                    | Node::Prefetch { n: 0 }
                    | Node::FlatMap
            ) && !matches!(n, Node::Map { udf, .. } if udf == "identity")
        })
        .collect()
}

/// Fuse adjacent `map(a) . map(b)` into `map("a+b")`, keeping the max of
/// the two parallelism settings (AUTOTUNE = 0 wins if either side asks).
fn fuse_maps(nodes: Vec<Node>) -> Vec<Node> {
    let mut out: Vec<Node> = Vec::with_capacity(nodes.len());
    for n in nodes {
        match (out.last_mut(), &n) {
            (
                Some(Node::Map { udf: prev_udf, parallelism: prev_p }),
                Node::Map { udf, parallelism },
            ) => {
                *prev_udf = format!("{prev_udf}+{udf}");
                *prev_p = if *prev_p == 0 || *parallelism == 0 {
                    0
                } else {
                    (*prev_p).max(*parallelism)
                };
            }
            _ => out.push(n),
        }
    }
    out
}

/// Ensure the pipeline ends with a prefetch so downstream consumption
/// overlaps production (tf.data injects the same).
fn inject_prefetch(mut nodes: Vec<Node>, depth: u32) -> Vec<Node> {
    match nodes.last() {
        Some(Node::Prefetch { .. }) | None => nodes,
        _ => {
            nodes.push(Node::Prefetch { n: depth });
            nodes
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::exec::{Executor, ExecutorConfig};
    use crate::data::graph::PipelineBuilder;
    use crate::data::udf::UdfRegistry;
    use crate::storage::ObjectStore;

    #[test]
    fn dead_nodes_removed() {
        let g = GraphDef {
            nodes: vec![
                Node::SourceRange { n: 10 },
                Node::Repeat { n: 1 },
                Node::Take { n: u64::MAX },
                Node::Skip { n: 0 },
                Node::Shuffle { buffer: 1, seed: 0 },
                Node::Map { udf: "identity".into(), parallelism: 1 },
                Node::Prefetch { n: 0 },
                Node::Batch { size: 2, drop_remainder: true },
            ],
        };
        let o = optimize(&g, &OptimizeOptions { prefetch_injection: false, ..Default::default() });
        assert_eq!(
            o.nodes,
            vec![Node::SourceRange { n: 10 }, Node::Batch { size: 2, drop_remainder: true }]
        );
    }

    #[test]
    fn maps_fuse_pairwise_and_transitively() {
        let g = PipelineBuilder::source_range(4)
            .map_parallel("a", 2)
            .map_parallel("b", 8)
            .map("c")
            .build();
        let o = optimize(&g, &OptimizeOptions { prefetch_injection: false, ..Default::default() });
        assert_eq!(o.nodes.len(), 2);
        assert_eq!(o.nodes[1], Node::Map { udf: "a+b+c".into(), parallelism: 8 });
    }

    #[test]
    fn autotune_parallelism_dominates_fusion() {
        let g = PipelineBuilder::source_range(4).map_parallel("a", 2).map_autotune("b").build();
        let o = optimize(&g, &OptimizeOptions { prefetch_injection: false, ..Default::default() });
        assert_eq!(o.nodes[1], Node::Map { udf: "a+b".into(), parallelism: 0 });
    }

    #[test]
    fn prefetch_injected_only_when_missing() {
        let g = PipelineBuilder::source_range(4).batch(2).build();
        let o = optimize(&g, &OptimizeOptions::default());
        assert_eq!(*o.nodes.last().unwrap(), Node::Prefetch { n: 2 });
        let g2 = PipelineBuilder::source_range(4).batch(2).prefetch(8).build();
        let o2 = optimize(&g2, &OptimizeOptions::default());
        assert_eq!(*o2.nodes.last().unwrap(), Node::Prefetch { n: 8 });
        assert_eq!(o2.nodes.len(), 3);
    }

    #[test]
    fn fixpoint_chains_passes() {
        // identity maps removed, then the two surviving maps fuse.
        let g = PipelineBuilder::source_range(4)
            .map("a")
            .map("identity")
            .map("b")
            .build();
        let o = optimize(&g, &OptimizeOptions { prefetch_injection: false, ..Default::default() });
        assert_eq!(o.nodes[1], Node::Map { udf: "a+b".into(), parallelism: 1 });
    }

    #[test]
    fn optimized_graph_is_semantically_equal() {
        let store = ObjectStore::in_memory();
        let udfs = UdfRegistry::with_builtins();
        udfs.register_fn("x2", |mut e: crate::data::Element| {
            let v = e.tensors[0].as_i32()[0] * 2;
            e.tensors[0] = crate::data::Tensor::scalar_i32(v);
            Ok(e)
        });
        udfs.register_fn("plus1", |mut e: crate::data::Element| {
            let v = e.tensors[0].as_i32()[0] + 1;
            e.tensors[0] = crate::data::Tensor::scalar_i32(v);
            Ok(e)
        });
        let ex = Executor::new(ExecutorConfig::local(store, udfs, 0));
        let g = PipelineBuilder::source_range(10)
            .map("x2")
            .map("plus1")
            .map("identity")
            .take(u64::MAX)
            .batch(2)
            .build();
        let o = optimize(&g, &OptimizeOptions::default());
        let a: Vec<_> = ex.collect(&g).unwrap().iter().map(|e| e.tensors[0].as_i32()).collect();
        let b: Vec<_> = ex.collect(&o).unwrap().iter().map(|e| e.tensors[0].as_i32()).collect();
        assert_eq!(a, b);
        assert!(o.nodes.len() < g.nodes.len());
    }
}
