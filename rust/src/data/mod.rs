//! The tf.data-like input pipeline framework.
//!
//! tf.data service distributes *serialized pipeline graphs* from clients to
//! workers (§3.1: "The dispatcher distributes a tf.data computation graph
//! representing the input data pipeline to all available workers"), so the
//! service is only meaningful on top of a real pipeline framework. This
//! module provides one:
//!
//! * [`element`] — [`element::Tensor`] / [`element::Element`]: the unit of
//!   data flowing through pipelines (a sample or a batch).
//! * [`graph`] — the serializable dataset graph ([`graph::GraphDef`]) with
//!   the standard operator set: source, map, filter, shuffle, batch,
//!   padded-batch, prefetch, repeat, take, cache, interleave, plus the
//!   NLP operators from Fig. 7 (`bucket_by_sequence_length`,
//!   `group_by_window`, `flat_map`).
//! * [`udf`] — user-defined function registry. UDFs are referenced by name
//!   in the graph (they execute on whichever worker the graph lands on);
//!   the registry holds native Rust UDFs and XLA-artifact UDFs backed by
//!   the AOT-compiled Pallas/JAX preprocessing kernels.
//! * [`exec`] — pull-based executor: builds an iterator tree from a graph,
//!   with parallel map (worker thread pool) and background prefetch.
//! * [`optimize`] — static graph rewrites (map fusion, dead transform
//!   elimination, prefetch injection), mirroring tf.data's pre-execution
//!   optimization passes (§3.2).
//! * [`autotune`] — runtime parallelism tuning (the AUTOTUNE stand-in).

pub mod autotune;
pub mod element;
pub mod exec;
pub mod graph;
pub mod optimize;
pub mod udf;

pub use element::{DType, Element, Tensor};
pub use exec::{Executor, ExecutorConfig, SplitProvider};
pub use graph::{GraphDef, Node};
pub use udf::UdfRegistry;

/// Pipeline-level errors.
#[derive(Debug)]
pub enum DataError {
    Storage(crate::storage::StorageError),
    Wire(crate::wire::WireError),
    UnknownUdf(String),
    UdfFailed { name: String, msg: String },
    Shape(String),
    InvalidGraph(String),
    Other(String),
}

impl std::fmt::Display for DataError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DataError::Storage(e) => write!(f, "storage: {e}"),
            DataError::Wire(e) => write!(f, "wire: {e}"),
            DataError::UnknownUdf(name) => write!(f, "unknown udf: {name}"),
            DataError::UdfFailed { name, msg } => write!(f, "udf {name} failed: {msg}"),
            DataError::Shape(msg) => write!(f, "shape mismatch: {msg}"),
            DataError::InvalidGraph(msg) => write!(f, "invalid graph: {msg}"),
            DataError::Other(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for DataError {}

impl From<crate::storage::StorageError> for DataError {
    fn from(e: crate::storage::StorageError) -> DataError {
        DataError::Storage(e)
    }
}

impl From<crate::wire::WireError> for DataError {
    fn from(e: crate::wire::WireError) -> DataError {
        DataError::Wire(e)
    }
}

pub type DataResult<T> = Result<T, DataError>;
