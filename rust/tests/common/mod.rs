//! Shared e2e test harness: cluster spin-up, stable-address endpoints, a
//! journal-backed dispatcher restart helper, and a deterministic seeded
//! fault injector. Dedupes the scaffolding previously copy-pasted across
//! `service_e2e.rs`, `coordinated_prefetch.rs`, `stream_session.rs`, and
//! `properties.rs`; each integration-test crate pulls it in via
//! `mod common;`, so not every crate uses every helper.
#![allow(dead_code)]

use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::rpc::{call_typed, Pool};
use tfdatasvc::service::dispatcher::{Dispatcher, DispatcherConfig};
use tfdatasvc::service::proto::{
    dispatcher_methods, worker_methods, GetOrCreateJobReq, GetOrCreateJobResp, ProcessingMode,
    RegisterDatasetReq, RegisterDatasetResp, SharingMode, ShardingPolicy, WorkerStatusReq,
    WorkerStatusResp,
};
use tfdatasvc::service::worker::{Worker, WorkerConfig};
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::storage::ObjectStore;
use tfdatasvc::util::rng::Rng;

/// Default RPC deadline for raw protocol-level calls in tests.
pub const T: Duration = Duration::from_secs(5);

// ------------------------------------------------------------ simple spin-up

/// In-memory dispatcher with default config (the pre-harness helper the
/// e2e files shared by copy-paste).
pub fn start_dispatcher() -> Dispatcher {
    Dispatcher::start("127.0.0.1:0", DispatcherConfig::default()).unwrap()
}

/// Worker with default config over `store`, registered with `dispatcher`.
pub fn start_worker(dispatcher: &Dispatcher, store: Arc<ObjectStore>) -> Worker {
    let cfg = WorkerConfig::new(store, UdfRegistry::with_builtins());
    Worker::start("127.0.0.1:0", &dispatcher.addr(), cfg).unwrap()
}

/// Coordinated-reads client config: OFF sharding, named job (coordinated
/// consumers group explicitly), one slot per consumer.
pub fn coord_cfg(job_name: &str, num_consumers: u32, consumer_index: u32) -> ServiceClientConfig {
    ServiceClientConfig {
        sharding: ShardingPolicy::Off,
        mode: ProcessingMode::Coordinated,
        job_name: job_name.into(),
        num_consumers,
        consumer_index,
        ..Default::default()
    }
}

/// Fresh per-process temp journal path (removed if it already exists).
pub fn journal_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("tfdatasvc-e2e-journals");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p
}

/// Fault-injection seed: `TFDATASVC_FAULT_SEED` when set (the CI hygiene
/// job runs the suite under several fixed seeds), else `default`.
pub fn fault_seed(default: u64) -> u64 {
    std::env::var("TFDATASVC_FAULT_SEED")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(default)
}

/// Register `graph` + an anonymous independent job through raw dispatcher
/// RPCs (no client fetcher machinery), then wait until the worker has the
/// task. The protocol-level tests drive the wire surface directly from
/// here.
pub fn raw_independent_job(
    graph: &tfdatasvc::data::graph::GraphDef,
    udfs: UdfRegistry,
) -> (Dispatcher, Worker, Pool, u64, u64) {
    let d = start_dispatcher();
    let store = ObjectStore::in_memory();
    let w = Worker::start("127.0.0.1:0", &d.addr(), WorkerConfig::new(store, udfs)).unwrap();
    let pool = Pool::with_defaults();

    let reg: RegisterDatasetResp = call_typed(
        &pool,
        &d.addr(),
        dispatcher_methods::REGISTER_DATASET,
        &RegisterDatasetReq { graph: graph.clone(), udf_digests: vec![] },
        T,
    )
    .unwrap();
    let job: GetOrCreateJobResp = call_typed(
        &pool,
        &d.addr(),
        dispatcher_methods::GET_OR_CREATE_JOB,
        &GetOrCreateJobReq {
            dataset_id: reg.dataset_id,
            job_name: String::new(),
            sharding: ShardingPolicy::Dynamic,
            mode: ProcessingMode::Independent,
            num_consumers: 0,
            sharing: SharingMode::Off,
        },
        T,
    )
    .unwrap();

    // The task reaches the worker on its next heartbeat.
    let deadline = Instant::now() + T;
    loop {
        let st: WorkerStatusResp =
            call_typed(&pool, &w.addr(), worker_methods::WORKER_STATUS, &WorkerStatusReq {}, T)
                .unwrap();
        if st.active_tasks.contains(&job.job_id) {
            break;
        }
        assert!(Instant::now() < deadline, "task never reached the worker");
        thread::sleep(Duration::from_millis(10));
    }
    (d, w, pool, job.job_id, job.client_id)
}

// ------------------------------------------------------- stable addresses

/// A tiny TCP forwarder giving a service endpoint a **stable address**
/// across process restarts — the test-harness analogue of the VIP /
/// service name a production deployment puts in front of the dispatcher
/// and each worker. Restarting a component re-binds an ephemeral port;
/// pointing the forwarder's backend at the new port keeps every peer's
/// cached address valid (and avoids re-binding a just-closed port, which
/// TIME_WAIT makes flaky). While the backend is empty (component down),
/// incoming connections are accepted and immediately dropped, so peers
/// observe connection failures exactly as during a real restart.
pub struct StableAddr {
    addr: String,
    backend: Arc<Mutex<String>>,
    stop: Arc<AtomicBool>,
}

impl StableAddr {
    pub fn start() -> StableAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let backend = Arc::new(Mutex::new(String::new()));
        let stop = Arc::new(AtomicBool::new(false));
        {
            let backend = backend.clone();
            let stop = stop.clone();
            thread::Builder::new()
                .name(format!("stable-addr-{addr}"))
                .spawn(move || loop {
                    if stop.load(Ordering::SeqCst) {
                        return;
                    }
                    match listener.accept() {
                        Ok((down, _)) => {
                            let target = backend.lock().unwrap().clone();
                            thread::Builder::new()
                                .name("stable-addr-conn".into())
                                .spawn(move || splice(down, &target))
                                .ok();
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => return,
                    }
                })
                .unwrap();
        }
        StableAddr { addr, backend, stop }
    }

    /// The stable front address peers should dial.
    pub fn addr(&self) -> String {
        self.addr.clone()
    }

    /// Point the front at a (new) live backend.
    pub fn set_backend(&self, addr: &str) {
        *self.backend.lock().unwrap() = addr.to_string();
    }

    /// Take the component "down": connections drop until a new backend is
    /// set.
    pub fn clear_backend(&self) {
        self.backend.lock().unwrap().clear();
    }
}

impl Drop for StableAddr {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
    }
}

/// Bidirectional byte forwarding until either side closes.
fn splice(down: TcpStream, target: &str) {
    if target.is_empty() {
        return; // component down: drop the connection
    }
    let Ok(up) = TcpStream::connect(target) else { return };
    down.set_nodelay(true).ok();
    up.set_nodelay(true).ok();
    let (Ok(mut c2s_r), Ok(mut c2s_w)) = (down.try_clone(), up.try_clone()) else { return };
    let h = thread::Builder::new().name("stable-addr-up".into()).spawn(move || {
        let _ = std::io::copy(&mut c2s_r, &mut c2s_w);
        let _ = c2s_w.shutdown(Shutdown::Both);
        let _ = c2s_r.shutdown(Shutdown::Both);
    });
    let mut s2c_r = up;
    let mut s2c_w = down;
    let _ = std::io::copy(&mut s2c_r, &mut s2c_w);
    let _ = s2c_w.shutdown(Shutdown::Both);
    let _ = s2c_r.shutdown(Shutdown::Both);
    if let Ok(h) = h {
        let _ = h.join();
    }
}

// ---------------------------------------------------------------- cluster

struct WorkerSlot {
    front: StableAddr,
    worker: Option<Worker>,
}

/// A dispatcher + N workers, each behind a [`StableAddr`], with
/// kill/revive/restart controls. Interior mutability throughout so a
/// ticker thread (and the test body) can share one `Arc<Cluster>`.
pub struct Cluster {
    pub store: Arc<ObjectStore>,
    dcfg: DispatcherConfig,
    dfront: StableAddr,
    dispatcher: Mutex<Option<Arc<Dispatcher>>>,
    workers: Mutex<Vec<WorkerSlot>>,
    /// Template config cloned for every spawned / revived worker.
    wcfg: Mutex<WorkerConfig>,
}

impl Cluster {
    pub fn start(num_workers: usize) -> Arc<Cluster> {
        Self::with_config(num_workers, DispatcherConfig::default())
    }

    pub fn with_config(num_workers: usize, dcfg: DispatcherConfig) -> Arc<Cluster> {
        let store = ObjectStore::in_memory();
        let udfs = UdfRegistry::with_builtins();
        Self::with_parts(num_workers, dcfg, store, udfs)
    }

    pub fn with_parts(
        num_workers: usize,
        mut dcfg: DispatcherConfig,
        store: Arc<ObjectStore>,
        udfs: UdfRegistry,
    ) -> Arc<Cluster> {
        let dfront = StableAddr::start();
        // Mirror the production orchestrator wiring: the cluster store is
        // also the spill tier, so the dispatcher can GC superseded
        // snapshots' objects.
        if dcfg.store.is_none() {
            dcfg.store = Some(store.clone());
        }
        let d = Dispatcher::start("127.0.0.1:0", dcfg.clone()).unwrap();
        dfront.set_backend(&d.addr());
        let wcfg = WorkerConfig::new(store.clone(), udfs);
        let cluster = Arc::new(Cluster {
            store,
            dcfg,
            dfront,
            dispatcher: Mutex::new(Some(Arc::new(d))),
            workers: Mutex::new(Vec::new()),
            wcfg: Mutex::new(wcfg),
        });
        for _ in 0..num_workers {
            cluster.add_worker();
        }
        cluster
    }

    /// The stable dispatcher address (valid across restarts).
    pub fn dispatcher_addr(&self) -> String {
        self.dfront.addr()
    }

    pub fn dispatcher(&self) -> Arc<Dispatcher> {
        self.dispatcher.lock().unwrap().clone().expect("dispatcher is up")
    }

    /// Mutate the template WorkerConfig used by `add_worker` and
    /// `revive_worker` (call before adding workers).
    pub fn set_worker_config(&self, f: impl FnOnce(&mut WorkerConfig)) {
        f(&mut self.wcfg.lock().unwrap());
    }

    pub fn add_worker(&self) -> usize {
        let front = StableAddr::start();
        let mut cfg = self.wcfg.lock().unwrap().clone();
        cfg.advertise_addr = Some(front.addr());
        let w = Worker::start("127.0.0.1:0", &self.dispatcher_addr(), cfg).unwrap();
        front.set_backend(&w.addr());
        let mut ws = self.workers.lock().unwrap();
        ws.push(WorkerSlot { front, worker: Some(w) });
        ws.len() - 1
    }

    /// The worker's stable (advertised) address.
    pub fn worker_addr(&self, i: usize) -> String {
        self.workers.lock().unwrap()[i].front.addr()
    }

    /// Run `f` against the live worker handle (metrics assertions).
    pub fn with_worker<R>(&self, i: usize, f: impl FnOnce(&Worker) -> R) -> Option<R> {
        self.workers.lock().unwrap()[i].worker.as_ref().map(f)
    }

    /// Preempt worker `i`: data server severed, heartbeats stop, the
    /// stable address goes dark.
    pub fn kill_worker(&self, i: usize) {
        let mut ws = self.workers.lock().unwrap();
        ws[i].front.clear_backend();
        if let Some(w) = ws[i].worker.take() {
            w.shutdown();
        }
    }

    /// Revive worker `i` behind the same stable address: it re-registers
    /// as the *same* logical worker (identity = advertised address), so
    /// its round residues re-balance back after the hysteresis window.
    pub fn revive_worker(&self, i: usize) {
        let mut ws = self.workers.lock().unwrap();
        assert!(ws[i].worker.is_none(), "worker {i} is already up");
        let mut cfg = self.wcfg.lock().unwrap().clone();
        cfg.advertise_addr = Some(ws[i].front.addr());
        let w = Worker::start("127.0.0.1:0", &self.dispatcher_addr(), cfg).unwrap();
        ws[i].front.set_backend(&w.addr());
        ws[i].worker = Some(w);
    }

    /// Kill the dispatcher (journal intact) and restart it after
    /// `downtime`, behind the same stable address. Pointless without a
    /// `journal_path` in the config — state would not survive.
    pub fn restart_dispatcher(&self, downtime: Duration) {
        self.dfront.clear_backend();
        let old = self.dispatcher.lock().unwrap().take();
        drop(old); // server shutdown severs live connections
        thread::sleep(downtime);
        let d = Dispatcher::start("127.0.0.1:0", self.dcfg.clone()).unwrap();
        self.dfront.set_backend(&d.addr());
        *self.dispatcher.lock().unwrap() = Some(Arc::new(d));
    }

    /// One lease tick (the orchestrator control loop's job in production).
    pub fn tick(&self) {
        let d = self.dispatcher.lock().unwrap().clone();
        if let Some(d) = d {
            d.tick();
        }
    }

    /// A client dialing the stable dispatcher address.
    pub fn client(&self) -> ServiceClient {
        ServiceClient::new(&self.dispatcher_addr())
    }
}

/// Background lease ticker over the cluster's (possibly restarting)
/// dispatcher — the orchestrator control loop's job in production.
/// Stops (and joins) when the guard drops.
pub fn start_ticker(cluster: &Arc<Cluster>, interval: Duration) -> TickerGuard {
    let stop = Arc::new(AtomicBool::new(false));
    let c = cluster.clone();
    let s = stop.clone();
    let handle = thread::Builder::new()
        .name("cluster-ticker".into())
        .spawn(move || {
            while !s.load(Ordering::SeqCst) {
                c.tick();
                thread::sleep(interval);
            }
        })
        .unwrap();
    TickerGuard { stop, handle: Some(handle) }
}

pub struct TickerGuard {
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
}

impl Drop for TickerGuard {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

// --------------------------------------------------------- fault injector

/// One scripted fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultEvent {
    KillWorker(usize),
    ReviveWorker(usize),
    RestartDispatcher,
}

/// A fault scheduled at a consumer-progress point (apply the event once
/// the test has consumed `at_step` rounds/elements — progress-keyed, so
/// the schedule is timing-independent and reproducible).
#[derive(Debug, Clone, Copy)]
pub struct FaultPlan {
    pub at_step: u64,
    pub event: FaultEvent,
}

/// Deterministic seeded fault schedule: workers flap (never killing the
/// last one alive; every kill is eventually paired with a revive), the
/// dispatcher restarts once mid-run, and everything is back up well
/// before `steps` so the run can finish. Same seed -> same schedule.
pub fn seeded_fault_plan(seed: u64, num_workers: usize, steps: u64) -> Vec<FaultPlan> {
    let mut rng = Rng::new(seed);
    let mut plan = Vec::new();
    let mut up: Vec<usize> = (0..num_workers).collect();
    let mut down: Vec<usize> = Vec::new();
    let mut step = 2 + rng.below(3);
    let restart_at = steps / 3 + rng.below((steps / 3).max(1));
    let mut restarted = false;
    while step + 6 < steps {
        if !restarted && step >= restart_at {
            plan.push(FaultPlan { at_step: step, event: FaultEvent::RestartDispatcher });
            restarted = true;
        } else if !down.is_empty() && (up.len() <= 1 || rng.chance(0.6)) {
            let i = down.remove(rng.below_usize(down.len()));
            up.push(i);
            plan.push(FaultPlan { at_step: step, event: FaultEvent::ReviveWorker(i) });
        } else if up.len() > 1 {
            let i = up.remove(rng.below_usize(up.len()));
            down.push(i);
            plan.push(FaultPlan { at_step: step, event: FaultEvent::KillWorker(i) });
        }
        step += 2 + rng.below(5);
    }
    // Everything back up before the tail so the epoch can drain.
    for i in down {
        plan.push(FaultPlan { at_step: step, event: FaultEvent::ReviveWorker(i) });
        step += 1;
    }
    plan
}
