//! The paper's cost model — Equation (1), implemented verbatim.
//!
//! ```text
//! C = t · ( C_CPU · (n_W · CPU_u^W + n_T · CPU_a^T)
//!         + C_MEM · (n_W · MEM_u^W + n_T · MEM_a^T)
//!         + C_ACC · n_T · n_ACC/T )
//! ```
//!
//! Worker CPU/MEM are charged by *utilization* (unused multi-tenant
//! resources return to the pool); client-host CPU/MEM are charged by
//! *allocation* (dedicated machines). Accelerators are charged per
//! device. Open-source prices (June 2023, us-central1): TPU v2-8 VM
//! $4.50/h, n2-standard-8 $0.08/h.

/// Unit prices per hour.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// $/core/h.
    pub cpu_per_core_h: f64,
    /// $/GiB/h.
    pub mem_per_gib_h: f64,
    /// $/accelerator/h.
    pub acc_per_h: f64,
}

impl CostModel {
    /// Prices backed out of GCP list prices: an n2-standard-8
    /// (8 vCPU / 32 GiB) at $0.08/h ≈ $0.007/core/h + $0.0008/GiB/h;
    /// a TPU v2-8 VM at $4.50/h, less its 96 vCPU / 335 GiB host share,
    /// leaves ≈ $3.56/h for the 8 TPU cores ≈ $0.445/core/h.
    pub fn gcp_2023() -> CostModel {
        CostModel { cpu_per_core_h: 0.007, mem_per_gib_h: 0.0008, acc_per_h: 0.445 }
    }

    /// Production-like prices: recent-generation accelerators (TPU v4
    /// class) run several $/chip/h, which is what makes worker cost a
    /// rounding error next to accelerator time in the paper's Fig. 8b.
    pub fn production_like() -> CostModel {
        CostModel { cpu_per_core_h: 0.007, mem_per_gib_h: 0.0008, acc_per_h: 3.0 }
    }

    /// Equation (1). Times in hours, utilizations/allocations in
    /// cores / GiB, `n_acc_per_client` accelerator cores per client.
    #[allow(clippy::too_many_arguments)]
    pub fn job_cost(
        &self,
        t_hours: f64,
        n_workers: f64,
        worker_cpu_util_cores: f64,
        worker_mem_util_gib: f64,
        n_clients: f64,
        client_cpu_alloc_cores: f64,
        client_mem_alloc_gib: f64,
        n_acc_per_client: f64,
    ) -> JobCost {
        let cpu = self.cpu_per_core_h
            * (n_workers * worker_cpu_util_cores + n_clients * client_cpu_alloc_cores);
        let mem = self.mem_per_gib_h
            * (n_workers * worker_mem_util_gib + n_clients * client_mem_alloc_gib);
        let acc = self.acc_per_h * n_clients * n_acc_per_client;
        JobCost {
            total: t_hours * (cpu + mem + acc),
            cpu_component: t_hours * cpu,
            mem_component: t_hours * mem,
            acc_component: t_hours * acc,
        }
    }
}

/// Cost breakdown.
#[derive(Debug, Clone, Copy)]
pub struct JobCost {
    pub total: f64,
    pub cpu_component: f64,
    pub mem_component: f64,
    pub acc_component: f64,
}

/// Whole-VM pricing used for the open-source ResNet50 experiment:
/// training cost = TPU-VM hours × $4.50 + (workers+dispatcher) hours ×
/// $0.08.
pub fn resnet50_vm_cost(train_hours: f64, n_service_vms: f64) -> (f64, f64, f64) {
    let tpu = train_hours * 4.50;
    let service = train_hours * n_service_vms * 0.08;
    (tpu + service, tpu, service)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accelerators_dominate() {
        let m = CostModel::gcp_2023();
        let c = m.job_cost(10.0, 128.0, 6.0, 20.0, 4.0, 96.0, 335.0, 8.0);
        assert!(c.acc_component > c.cpu_component);
        assert!(c.acc_component > 0.5 * c.total, "accelerators are the dominant cost");
        assert!((c.total - (c.cpu_component + c.mem_component + c.acc_component)).abs() < 1e-9);
    }

    #[test]
    fn faster_job_with_more_workers_can_cost_less() {
        // The core §4.2 claim: paying for workers is worth it because the
        // job releases accelerators sooner. With production accelerator
        // prices the saving approaches the speedup (M1: 11.7x -> 10.8x).
        let m = CostModel::production_like();
        // Colocated: 11.7x longer, no workers.
        let colo = m.job_cost(11.7, 0.0, 0.0, 0.0, 4.0, 96.0, 335.0, 8.0);
        // Disaggregated: 1.0 h, 442 workers at ~6 cores utilized.
        let dis = m.job_cost(1.0, 442.0, 6.0, 24.0, 4.0, 96.0, 335.0, 8.0);
        assert!(dis.total < colo.total, "dis {} vs colo {}", dis.total, colo.total);
        let saving = colo.total / dis.total;
        assert!(saving > 8.0, "near-speedup saving, got {saving:.1}x");
    }

    #[test]
    fn resnet50_costs_match_paper() {
        // Paper: colocated 80.2$ (TPU only); disaggregated 40.6$ total
        // (31.2$ TPU + 9.4$ service with 17 VMs).
        let colo_hours = 80.2 / 4.50;
        let (colo_total, _, _) = resnet50_vm_cost(colo_hours, 0.0);
        assert!((colo_total - 80.2).abs() < 0.1);
        let dis_hours = colo_hours / 2.57; // 2.57x speedup
        let (dis_total, tpu, svc) = resnet50_vm_cost(dis_hours, 17.0);
        assert!((tpu - 31.2).abs() < 0.3, "tpu {tpu}");
        assert!((svc - 9.4).abs() < 0.5, "service {svc}");
        assert!((dis_total - 40.6).abs() < 0.7, "total {dis_total}");
        // 1.97x cost saving
        assert!((colo_total / dis_total - 1.97).abs() < 0.05);
    }

    #[test]
    fn worker_cost_charged_by_utilization() {
        let m = CostModel::gcp_2023();
        let idle = m.job_cost(1.0, 100.0, 0.5, 1.0, 1.0, 96.0, 335.0, 8.0);
        let busy = m.job_cost(1.0, 100.0, 7.5, 28.0, 1.0, 96.0, 335.0, 8.0);
        assert!(busy.cpu_component > idle.cpu_component * 5.0);
        // Accelerator cost unchanged.
        assert_eq!(busy.acc_component, idle.acc_component);
    }
}
