//! Fig. 8: end-to-end speedups (a) and cost reductions (b) from
//! horizontal scale-out, for M1, M2, M3, and ResNet50 — plus the live
//! closed loop (§3.1): a [`ScalingController`] right-sizing a real cell
//! under the fig2 burstiness trace, with every scale-down routed
//! through the two-phase graceful worker drain.
//!
//! Paper rows: speedup 11.7x / 110.3x / 2.9x / 2.57x (avg 31.7x), cost
//! saving 10.8x / 89.3x / 2.8x / 1.97x (avg 26.2x); M2 lands 8% short of
//! ideal; ResNet50 $80.2 -> $40.6.
//!
//! The live section asserts the acceptance criteria the autoscaling
//! subsystem ships under: the worker-count trajectory tracks offered
//! load (pool grows under bursts, drains back to the floor when calm)
//! and no client step stalls longer than ~one worker heartbeat while
//! workers drain away mid-job. `--smoke` shortens the trace for CI.
//! Results land in `out/bench_scaleout.json` and are mirrored to the
//! repo-root baseline `BENCH_scaleout.json` (trajectory included).

use std::sync::Arc;
use std::time::{Duration, Instant};

use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::metrics::{write_csv_rows, write_json_file};
use tfdatasvc::orchestrator::{AutoscalerConfig, Cell};
use tfdatasvc::service::dispatcher::DispatcherConfig;
use tfdatasvc::service::proto::{ProcessingMode, ShardingPolicy};
use tfdatasvc::service::{ScalingConfig, ScalingController, ServiceClient, ServiceClientConfig};
use tfdatasvc::sim::cost::{resnet50_vm_cost, CostModel};
use tfdatasvc::sim::des::{simulate_job, JobSimConfig};
use tfdatasvc::sim::fleet::burstiness_timeline;
use tfdatasvc::sim::models::model;
use tfdatasvc::storage::ObjectStore;
use tfdatasvc::util::hist::Samples;
use tfdatasvc::util::json::{obj, Json};

/// Per-element preprocessing cost for the live section: heavy enough
/// that a saturating consumer pins a producer core (clean utilization /
/// starvation signals), light enough that rounds still flow at a
/// measurable cadence on one worker.
const SPIN_PER_ELEMENT: Duration = Duration::from_micros(1500);

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!("=== Fig 8a: training throughput speedup over colocated ===");
    println!("{:<10} {:>10} {:>12} {:>10} {:>10} {:>8} {:>8}", "model", "colo b/s", "service b/s", "ideal b/s", "workers", "speedup", "paper");
    let mut rows = Vec::new();
    let mut speedups = Vec::new();
    let mut savings = Vec::new();
    for name in ["M1", "M2", "M3", "ResNet50"] {
        let m = model(name);
        let colo = simulate_job(m, &JobSimConfig::default());
        let dis = simulate_job(m, &JobSimConfig { n_workers: m.paper_workers, ..Default::default() });
        let speedup = dis.throughput_bps / colo.throughput_bps;
        speedups.push(speedup);
        println!(
            "{:<10} {:>10.2} {:>12.2} {:>10.2} {:>10} {:>7.1}x {:>7.1}x",
            name, colo.throughput_bps, dis.throughput_bps, m.ideal_bps, m.paper_workers, speedup, m.paper_speedup
        );

        // Fig 8b: cost via Eq. (1): job time shrinks by the speedup; pay
        // for workers' utilized CPU/RAM meanwhile.
        let cm = CostModel::production_like();
        let t_colo = 10.0; // reference colocated job length (hours)
        let t_dis = t_colo / speedup;
        let clients = (m.accelerators as f64 / 8.0).max(1.0);
        let colo_cost = cm.job_cost(t_colo, 0.0, 0.0, 0.0, clients, 96.0, 335.0, 8.0);
        let dis_cost = cm.job_cost(
            t_dis,
            m.paper_workers as f64,
            m.worker_cpu_cores * dis.worker_utilization,
            8.0,
            clients,
            96.0,
            335.0,
            8.0,
        );
        let saving = colo_cost.total / dis_cost.total;
        savings.push(saving);
        rows.push(vec![
            name.to_string(),
            format!("{speedup:.2}"),
            format!("{:.2}", m.paper_speedup),
            format!("{saving:.2}"),
            format!("{:.2}", m.paper_cost_saving),
        ]);
    }
    println!("\n=== Fig 8b: cost reduction (Eq. 1, production-like prices) ===");
    println!("{:<10} {:>10} {:>12}", "model", "saving", "paper saving");
    for r in &rows {
        println!("{:<10} {:>9}x {:>11}x", r[0], r[3], r[4]);
    }
    let avg_speedup = speedups.iter().sum::<f64>() / speedups.len() as f64;
    let avg_saving = savings.iter().sum::<f64>() / savings.len() as f64;
    println!("\naverages: speedup {avg_speedup:.1}x (paper 31.7x), cost saving {avg_saving:.1}x (paper 26.2x)");

    // M2's 8% shortfall from client-side ingest pressure.
    let m2 = model("M2");
    let r = simulate_job(m2, &JobSimConfig { n_workers: m2.paper_workers, ..Default::default() });
    println!(
        "M2 ideal-gap: service {:.0} vs ideal {:.0} b/s ({:.0}% short; paper: 8%)",
        r.throughput_bps,
        m2.ideal_bps,
        (1.0 - r.throughput_bps / m2.ideal_bps) * 100.0
    );

    // ResNet50 open-source dollars.
    let colo_hours = 80.2 / 4.50;
    let (rn_colo, _, _) = resnet50_vm_cost(colo_hours, 0.0);
    let (rn_dis, tpu, svc) = resnet50_vm_cost(colo_hours / speedups[3], 17.0);
    println!(
        "ResNet50 dollars: colocated ${rn_colo:.1} -> disaggregated ${rn_dis:.1} (TPU ${tpu:.1} + service ${svc:.1}; paper: $80.2 -> $40.6)"
    );

    write_csv_rows("out/fig8.csv", "model,speedup,paper_speedup,cost_saving,paper_cost_saving", &rows).unwrap();

    // --- Live closed loop (§3.1): sense -> decide -> actuate over a
    // real cell. The fig2 burstiness trace modulates offered load — a
    // coordinated consumer steps flat-out through the preprocessing
    // bursts and trickles through the calm phases — while a
    // ScalingController watches worker CPU and client starvation from
    // the heartbeat plane and resizes the pool; every shrink runs the
    // two-phase revoke-ack-grant drain of the least-loaded worker.
    let (trace_secs, step_secs) = if smoke { (8.0, 4.0) } else { (16.0, 4.0) };
    let trace = burstiness_timeline(trace_secs, step_secs, 0.5, 0x0f16_0002);
    let dt = step_secs / 20.0;
    let (min_workers, max_workers) = (1usize, 4usize);

    let udfs = UdfRegistry::with_builtins();
    udfs.register_fn("bench.spin", |e| {
        let t0 = Instant::now();
        while t0.elapsed() < SPIN_PER_ELEMENT {
            std::hint::black_box(&t0);
        }
        Ok(e)
    });
    let cell =
        Arc::new(Cell::new(ObjectStore::in_memory(), udfs, DispatcherConfig::default()).unwrap());
    cell.scale_to(min_workers).unwrap();
    let ctl = ScalingController::start(
        cell.clone(),
        ScalingConfig {
            interval: Duration::from_millis(150),
            autoscaler: AutoscalerConfig {
                min_workers,
                max_workers,
                cooldown: Duration::from_millis(300),
                ..Default::default()
            },
        },
    );

    let live_graph = PipelineBuilder::source_range(10_000_000).map("bench.spin").build();
    let client = ServiceClient::new(&cell.dispatcher_addr());
    let mut it = client
        .distribute(
            &live_graph,
            ServiceClientConfig {
                sharding: ShardingPolicy::Off,
                mode: ProcessingMode::Coordinated,
                job_name: "fig8-closed-loop".into(),
                num_consumers: 1,
                consumer_index: 0,
                ..Default::default()
            },
        )
        .unwrap();
    // Warm up untimed: job registration and the first task attach cost a
    // couple of heartbeats and are not a scaling stall.
    for _ in 0..5 {
        let e = it.next().expect("warmup fetch failed").expect("stream ended early");
        std::hint::black_box(&e);
    }

    println!(
        "\n=== Fig 8 live closed loop: fig2 burstiness trace, {trace_secs:.0} s, pool {min_workers}..{max_workers}{} ===",
        if smoke { ", smoke" } else { "" }
    );
    let mut steps = Samples::new();
    let mut max_step = Duration::ZERO;
    let mut rounds = 0u64;
    let mut peak_workers = 0usize;
    let mut burst_w = Samples::new();
    let mut calm_w = Samples::new();
    let mut trajectory: Vec<Json> = Vec::new();
    let t_start = Instant::now();
    for p in &trace {
        // The trace's bimodal CPU demand is the offered load: burst
        // points consume flat-out (input-bound trainer), calm points
        // take one step per window (compute-bound trainer).
        let burst = p.cpu > 0.5;
        let window_end = Duration::from_secs_f64(p.t + dt);
        loop {
            let f0 = Instant::now();
            let e = it.next().expect("round fetch failed").expect("stream ended early");
            std::hint::black_box(&e);
            let d = f0.elapsed();
            steps.push(d.as_secs_f64() * 1e3);
            max_step = max_step.max(d);
            rounds += 1;
            if !burst || t_start.elapsed() >= window_end {
                break;
            }
        }
        while t_start.elapsed() < window_end {
            std::thread::sleep(Duration::from_millis(5));
        }
        let w = cell.worker_count();
        peak_workers = peak_workers.max(w);
        let phase_samples = if burst { &mut burst_w } else { &mut calm_w };
        phase_samples.push(w as f64);
        trajectory.push(obj([
            ("t", p.t.into()),
            ("offered_cpu", p.cpu.into()),
            ("burst", burst.into()),
            ("workers", (w as u64).into()),
        ]));
    }
    // Cool-down tail: hold offered load at idle until the controller
    // walks the pool back down to the floor through graceful drains.
    let deadline = Instant::now() + Duration::from_secs(15);
    while cell.worker_count() > min_workers {
        assert!(Instant::now() < deadline, "controller never drained back to the floor");
        let f0 = Instant::now();
        let e = it.next().expect("round fetch failed").expect("stream ended early");
        std::hint::black_box(&e);
        let d = f0.elapsed();
        steps.push(d.as_secs_f64() * 1e3);
        max_step = max_step.max(d);
        rounds += 1;
        std::thread::sleep(Duration::from_millis(150));
    }
    let final_workers = cell.worker_count();
    ctl.stop();

    let evaluations = ctl.metrics.counter("scaling/evaluations").get();
    let scale_ups = ctl.metrics.counter("scaling/scale_ups").get();
    let scale_downs = ctl.metrics.counter("scaling/scale_downs").get();
    let dm = cell.dispatcher().metrics();
    let drains_started = dm.counter("dispatcher/worker_drains_started").get();
    let drained = dm.counter("dispatcher/workers_drained").get();
    let skipped = client.metrics().counter("client/rounds_skipped_forward").get();
    println!(
        "{rounds} rounds; workers peak {peak_workers} (burst mean {:.2}, calm mean {:.2}) -> final \
         {final_workers}; {evaluations} evaluations, {scale_ups} scale-ups, {scale_downs} \
         scale-downs, {drains_started} drains started / {drained} drained; step p50 {:.2} ms p99 \
         {:.2} ms max {:.1} ms",
        burst_w.mean(),
        calm_w.mean(),
        steps.percentile(50.0),
        steps.percentile(99.0),
        max_step.as_secs_f64() * 1e3
    );

    // Acceptance: the trajectory tracks offered load within the
    // hysteresis bounds — bursts scale the pool up, calm + cooldown
    // converge it back to the floor — and scale-down is graceful.
    assert!(!trajectory.is_empty(), "the closed-loop trajectory must be non-empty");
    assert!(
        scale_ups >= 1 && peak_workers >= 2,
        "bursts must scale the pool up (peak {peak_workers}, {scale_ups} scale-ups)"
    );
    assert!(
        scale_downs >= 1 && drained >= (peak_workers - min_workers) as u64,
        "calm phases must drain the pool (drained {drained}, peak {peak_workers})"
    );
    assert_eq!(
        final_workers, min_workers,
        "the controller converges to the floor when offered load stays idle"
    );
    // Stall bound: the drain contract is that a losing owner serves its
    // residues until the gainer's grant activates, so no step waits out
    // a lease. One worker heartbeat (100 ms) is the protocol bound; 5x
    // covers CI scheduler noise.
    assert!(
        max_step < Duration::from_millis(500),
        "a step stalled {max_step:?} while the pool resized under load"
    );
    assert_eq!(skipped, 0, "a graceful drain must never trigger skip-forward");

    let bench_json = obj([
        ("bench", "fig8_scaleout".into()),
        ("smoke", smoke.into()),
        (
            "sim",
            obj([
                ("avg_speedup", avg_speedup.into()),
                ("paper_avg_speedup", 31.7.into()),
                ("avg_cost_saving", avg_saving.into()),
                ("paper_avg_cost_saving", 26.2.into()),
            ]),
        ),
        (
            "closed_loop",
            obj([
                (
                    "trace",
                    obj([
                        ("duration_s", trace_secs.into()),
                        ("step_time_s", step_secs.into()),
                        ("preprocess_fraction", 0.5.into()),
                        ("seed", 0x0f16_0002u64.into()),
                    ]),
                ),
                ("min_workers", (min_workers as u64).into()),
                ("max_workers", (max_workers as u64).into()),
                ("controller_interval_ms", 150u64.into()),
                ("worker_heartbeat_ms", 100u64.into()),
                ("rounds", rounds.into()),
                ("evaluations", evaluations.into()),
                ("scale_ups", scale_ups.into()),
                ("scale_downs", scale_downs.into()),
                ("worker_drains_started", drains_started.into()),
                ("workers_drained", drained.into()),
                ("peak_workers", (peak_workers as u64).into()),
                ("final_workers", (final_workers as u64).into()),
                ("burst_mean_workers", burst_w.mean().into()),
                ("calm_mean_workers", calm_w.mean().into()),
                ("step_p50_ms", steps.percentile(50.0).into()),
                ("step_p99_ms", steps.percentile(99.0).into()),
                ("max_step_ms", (max_step.as_secs_f64() * 1e3).into()),
                ("rounds_skipped_forward", skipped.into()),
                ("trajectory", Json::Arr(trajectory)),
            ]),
        ),
    ]);
    write_json_file("out/bench_scaleout.json", &bench_json).unwrap();
    // Also publish at the repo root under the stable name the roadmap
    // tracks (CI regenerates it every run; the checked-in copy is the
    // latest accepted baseline).
    write_json_file("BENCH_scaleout.json", &bench_json).unwrap();
    it.release();
    println!("fig8 OK -> out/fig8.csv + out/bench_scaleout.json + BENCH_scaleout.json");
}
