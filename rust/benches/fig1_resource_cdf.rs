//! Fig. 1: CDFs of normalized ML host CPU & RAM usage across the fleet.
//!
//! Paper: 73k colocated jobs over 24 h; heavy-tailed CDFs showing that no
//! single CPU:RAM provisioning fits. Regenerated from the documented
//! heavy-tailed fleet generator. Prints both CDFs and writes
//! `out/fig1_{cpu,ram}.csv`.

use tfdatasvc::metrics::write_csv;
use tfdatasvc::sim::fleet::generate_fleet;
use tfdatasvc::util::hist::{format_series, Samples};

fn main() {
    const N: usize = 73_000;
    let jobs = generate_fleet(N, 0xf1_6001);
    let mut cpu = Samples::from_vec(jobs.iter().map(|j| j.cpu).collect());
    let mut ram = Samples::from_vec(jobs.iter().map(|j| j.ram).collect());

    println!("=== Fig 1: fleet resource-usage CDFs ({N} jobs) ===");
    for (name, s) in [("CPU", &mut cpu), ("RAM", &mut ram)] {
        println!(
            "{name}: p10 {:.4}  p50 {:.4}  p90 {:.4}  p99 {:.4}  (normalized to peak)",
            s.percentile(10.0),
            s.percentile(50.0),
            s.percentile(90.0),
            s.percentile(99.0)
        );
    }
    let cpu_pts = cpu.cdf_points(50);
    let ram_pts = ram.cdf_points(50);
    print!("{}", format_series("CPU CDF (x = normalized usage, y = F(x))", &cpu_pts[..10]));
    print!("{}", format_series("RAM CDF (x = normalized usage, y = F(x))", &ram_pts[..10]));

    write_csv("out/fig1_cpu.csv", "normalized_cpu,cdf", &cpu_pts).unwrap();
    write_csv("out/fig1_ram.csv", "normalized_ram,cdf", &ram_pts).unwrap();

    // The figure's takeaway, asserted: any fixed provisioning point p
    // leaves a large fraction under-provisioned or wasteful.
    let p = cpu.percentile(50.0);
    let under = 1.0 - cpu.cdf_at(p);
    println!(
        "takeaway: provisioning at the CPU median leaves {:.0}% of jobs short and the rest \
         over-provisioned by up to {:.0}x",
        under * 100.0,
        p / cpu.percentile(10.0)
    );
    println!("fig1 OK -> out/fig1_cpu.csv, out/fig1_ram.csv");
}
