"""L1 Pallas kernel: fused transformer FFN block (matmul + GELU + matmul).

This is the MXU-facing hot-spot of the L2 train step: the position-wise
feed-forward block  y = gelu(x @ W1 + b1) @ W2 + b2.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the grid tiles the row
dimension (batch*seq) into blocks of `row_block`; each grid step stages one
(row_block, D) activation tile plus both weight matrices into VMEM and runs
two MXU matmuls back to back, keeping the (row_block, F) intermediate
entirely in VMEM — the intermediate never touches HBM, which is the fusion
win over the unfused jnp version (saves 2*rows*F*4 bytes of HBM traffic per
block). For the e2e model (D=128, F=512, row_block=128) the working set is

    x tile   128*128*4 = 64 KB
    W1       128*512*4 = 256 KB
    W2       512*128*4 = 256 KB
    h tile   128*512*4 = 256 KB
    out tile 128*128*4 = 64 KB          total ~0.9 MB << 16 MB VMEM

so double-buffering the x tile is trivially affordable, and both matmuls
land on the 128x128 MXU with full tiles (D and row_block are multiples of
128 by construction; F is a multiple of 128).

`interpret=True` for CPU-PJRT executability; see augment.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref):
    x = x_ref[...]
    # First matmul + bias on the MXU; accumulate in f32.
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    h = h + b1_ref[...]
    h = ref.gelu_ref(h)
    # Second matmul + bias; (row_block, F) stays resident in VMEM.
    y = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    out_ref[...] = y + b2_ref[...]


@functools.partial(jax.jit, static_argnames=("row_block",))
def ffn(x, w1, b1, w2, b2, row_block: int = 128):
    """Fused FFN over row-tiled activations.

    Args:
      x:  (N, D) float32; N need not divide row_block (padded internally).
      w1: (D, F), b1: (F,), w2: (F, D), b2: (D,).
      row_block: rows per grid step (MXU-friendly multiple of 8).

    Returns:
      (N, D) float32, allclose to ref.ffn_ref.
    """
    n, d = x.shape
    f = w1.shape[1]
    rb = min(row_block, max(8, n))
    n_pad = (n + rb - 1) // rb * rb
    xp = jnp.pad(x, ((0, n_pad - n), (0, 0))) if n_pad != n else x
    out = pl.pallas_call(
        _ffn_kernel,
        grid=(n_pad // rb,),
        in_specs=[
            pl.BlockSpec((rb, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), jnp.float32),
        interpret=True,
    )(xp, w1, b1, w2, b2)
    return out[:n] if n_pad != n else out


def _gelu_grad(z):
    """d/dz of the tanh-approximation GELU (matches ref.gelu_ref)."""
    k = 0.7978845608028654
    u = k * (z + 0.044715 * z * z * z)
    t = jnp.tanh(u)
    return 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * k * (1.0 + 3 * 0.044715 * z * z)


@jax.custom_vjp
def ffn_trainable(x, w1, b1, w2, b2):
    """Differentiable wrapper: Pallas kernel forward, analytic backward.

    Interpret-mode pallas_call has no reverse-mode rule, so the L2 train
    step uses this wrapper: the forward pass runs the fused kernel, the
    backward pass is closed-form jnp (it lowers into the same train-step
    HLO artifact, so Rust still executes a single fused module).
    """
    return ffn(x, w1, b1, w2, b2)


def _ffn_fwd(x, w1, b1, w2, b2):
    return ffn(x, w1, b1, w2, b2), (x, w1, b1, w2, b2)


def _ffn_bwd(saved, dy):
    # Residuals may arrive as raw host arrays when the caller passed numpy;
    # normalize to jnp so matmul works under every tracing mode.
    x, w1, b1, w2, b2 = (jnp.asarray(t) for t in saved)
    dy = jnp.asarray(dy)
    z = x @ w1 + b1
    h = ref.gelu_ref(z)
    dw2 = h.T @ dy
    db2 = jnp.sum(dy, axis=0)
    dh = dy @ w2.T
    dz = dh * _gelu_grad(z)
    dw1 = x.T @ dz
    db1 = jnp.sum(dz, axis=0)
    dx = dz @ w1.T
    return dx, dw1, db1, dw2, db2


ffn_trainable.defvjp(_ffn_fwd, _ffn_bwd)


def vmem_bytes(row_block: int, d: int, f: int) -> int:
    """Estimated VMEM working set per grid step (for DESIGN.md §Perf)."""
    return 4 * (row_block * d + d * f + f + f * d + d + row_block * f + row_block * d)


def mxu_flops(n: int, d: int, f: int) -> int:
    """MXU FLOPs for one call (both matmuls)."""
    return 2 * n * d * f * 2
