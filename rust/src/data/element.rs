//! Tensors and elements: the values flowing through pipelines.
//!
//! An [`Element`] is a tuple of named-free tensors — one sample before
//! batching, one batch after. Tensors carry dtype + shape + raw
//! little-endian bytes, matching what the PJRT runtime consumes.

use crate::wire::{Decode, Encode, Reader, WireError, WireResult, Writer};

/// Supported dtypes (matches the artifact manifest's dtype names).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    U8,
    U32,
    I32,
    I64,
    F32,
}

impl DType {
    pub fn size_of(self) -> usize {
        match self {
            DType::U8 => 1,
            DType::U32 | DType::I32 | DType::F32 => 4,
            DType::I64 => 8,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::U8 => "u8",
            DType::U32 => "u32",
            DType::I32 => "i32",
            DType::I64 => "i64",
            DType::F32 => "f32",
        }
    }

    pub fn from_name(s: &str) -> Option<DType> {
        Some(match s {
            "u8" => DType::U8,
            "u32" => DType::U32,
            "i32" => DType::I32,
            "i64" => DType::I64,
            "f32" => DType::F32,
            _ => return None,
        })
    }

    fn to_tag(self) -> u8 {
        match self {
            DType::U8 => 0,
            DType::U32 => 1,
            DType::I32 => 2,
            DType::I64 => 3,
            DType::F32 => 4,
        }
    }

    fn from_tag(t: u8) -> WireResult<DType> {
        Ok(match t {
            0 => DType::U8,
            1 => DType::U32,
            2 => DType::I32,
            3 => DType::I64,
            4 => DType::F32,
            tag => return Err(WireError::BadTag { tag, ty: "DType" }),
        })
    }
}

/// A dense tensor: dtype, shape, and little-endian packed data.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn new(dtype: DType, shape: Vec<usize>, data: Vec<u8>) -> Tensor {
        debug_assert_eq!(
            data.len(),
            shape.iter().product::<usize>() * dtype.size_of(),
            "tensor data length mismatch"
        );
        Tensor { dtype, shape, data }
    }

    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    // ----- constructors -----

    pub fn from_f32(shape: Vec<usize>, vals: &[f32]) -> Tensor {
        assert_eq!(vals.len(), shape.iter().product::<usize>());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { dtype: DType::F32, shape, data }
    }

    pub fn from_i32(shape: Vec<usize>, vals: &[i32]) -> Tensor {
        assert_eq!(vals.len(), shape.iter().product::<usize>());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { dtype: DType::I32, shape, data }
    }

    pub fn from_u32(shape: Vec<usize>, vals: &[u32]) -> Tensor {
        assert_eq!(vals.len(), shape.iter().product::<usize>());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { dtype: DType::U32, shape, data }
    }

    pub fn from_u8(shape: Vec<usize>, vals: Vec<u8>) -> Tensor {
        assert_eq!(vals.len(), shape.iter().product::<usize>());
        Tensor { dtype: DType::U8, shape, data: vals }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::from_f32(vec![], &[v])
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::from_i32(vec![], &[v])
    }

    pub fn scalar_u32(v: u32) -> Tensor {
        Tensor::from_u32(vec![], &[v])
    }

    // ----- typed views -----

    pub fn as_f32(&self) -> Vec<f32> {
        assert_eq!(self.dtype, DType::F32);
        self.data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    pub fn as_i32(&self) -> Vec<i32> {
        assert_eq!(self.dtype, DType::I32);
        self.data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    pub fn as_u32(&self) -> Vec<u32> {
        assert_eq!(self.dtype, DType::U32);
        self.data
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    pub fn as_u8(&self) -> &[u8] {
        assert_eq!(self.dtype, DType::U8);
        &self.data
    }

    pub fn f32_at(&self, idx: usize) -> f32 {
        assert_eq!(self.dtype, DType::F32);
        f32::from_le_bytes(self.data[idx * 4..idx * 4 + 4].try_into().unwrap())
    }

    /// Stack `n` same-shaped tensors into one with a leading batch dim.
    pub fn stack(tensors: &[Tensor]) -> Result<Tensor, String> {
        let first = tensors.first().ok_or("cannot stack zero tensors")?;
        let mut data = Vec::with_capacity(first.data.len() * tensors.len());
        for t in tensors {
            if t.dtype != first.dtype || t.shape != first.shape {
                return Err(format!(
                    "stack mismatch: {:?}{:?} vs {:?}{:?}",
                    first.dtype, first.shape, t.dtype, t.shape
                ));
            }
            data.extend_from_slice(&t.data);
        }
        let mut shape = vec![tensors.len()];
        shape.extend_from_slice(&first.shape);
        Ok(Tensor { dtype: first.dtype, shape, data })
    }

    /// Stack variable-length rank-1 tensors, padding each to the longest
    /// with `pad_byte`-filled elements (the padded-batch primitive).
    pub fn stack_padded(tensors: &[Tensor], pad_value_le: &[u8]) -> Result<Tensor, String> {
        let first = tensors.first().ok_or("cannot stack zero tensors")?;
        let esz = first.dtype.size_of();
        assert_eq!(pad_value_le.len(), esz);
        let max_len = tensors.iter().map(|t| t.shape[0]).max().unwrap();
        let mut data = Vec::with_capacity(tensors.len() * max_len * esz);
        for t in tensors {
            if t.dtype != first.dtype || t.rank() != 1 {
                return Err("stack_padded wants same-dtype rank-1 tensors".into());
            }
            data.extend_from_slice(&t.data);
            for _ in t.shape[0]..max_len {
                data.extend_from_slice(pad_value_le);
            }
        }
        Ok(Tensor { dtype: first.dtype, shape: vec![tensors.len(), max_len], data })
    }
}

impl Encode for Tensor {
    fn encode(&self, w: &mut Writer) {
        w.put_u8(self.dtype.to_tag());
        w.put_u32(self.shape.len() as u32);
        for d in &self.shape {
            w.put_u64(*d as u64);
        }
        w.put_bytes(&self.data);
    }
}

impl Decode for Tensor {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        let dtype = DType::from_tag(r.get_u8()?)?;
        let rank = r.get_u32()? as usize;
        r.check_count(rank, 8)?;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(r.get_u64()? as usize);
        }
        let data = r.get_bytes()?;
        if data.len() != shape.iter().product::<usize>() * dtype.size_of() {
            return Err(WireError::Other(format!(
                "tensor bytes {} inconsistent with shape {:?} dtype {}",
                data.len(),
                shape,
                dtype.name()
            )));
        }
        Ok(Tensor { dtype, shape, data })
    }
}

/// An element: a tuple of tensors (e.g. `(image, label)` or
/// `(tokens, label)`), plus bookkeeping used by tests and the coordinated
/// reads scheduler.
#[derive(Debug, Clone, PartialEq)]
pub struct Element {
    pub tensors: Vec<Tensor>,
    /// Source-sample ids contributing to this element (1 before batching,
    /// `batch_size` after). Lets tests verify visitation guarantees.
    pub ids: Vec<u64>,
    /// Sequence-length bucket assigned by `bucket_by_sequence_length`;
    /// the coordinated-reads scheduler groups batches by this key.
    pub bucket: Option<u32>,
}

impl Element {
    pub fn new(tensors: Vec<Tensor>) -> Element {
        Element { tensors, ids: vec![], bucket: None }
    }

    pub fn with_ids(tensors: Vec<Tensor>, ids: Vec<u64>) -> Element {
        Element { tensors, ids, bucket: None }
    }

    pub fn byte_len(&self) -> usize {
        self.tensors.iter().map(|t| t.byte_len()).sum()
    }

    /// Leading dimension of the first tensor, if any — the batch size for
    /// batched elements.
    pub fn batch_dim(&self) -> Option<usize> {
        self.tensors.first().and_then(|t| t.shape.first().copied())
    }
}

impl Encode for Element {
    fn encode(&self, w: &mut Writer) {
        crate::wire::encode_vec(&self.tensors, w);
        self.ids.encode(w);
        self.bucket.encode(w);
    }
}

impl Decode for Element {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        let tensors = crate::wire::decode_vec(r)?;
        let ids = Vec::<u64>::decode(r)?;
        let bucket = Option::<u32>::decode(r)?;
        Ok(Element { tensors, ids, bucket })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_constructors_and_views() {
        let t = Tensor::from_f32(vec![2, 2], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.num_elements(), 4);
        assert_eq!(t.byte_len(), 16);
        assert_eq!(t.as_f32(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.f32_at(2), 3.0);
        let u = Tensor::from_u32(vec![3], &[7, 8, 9]);
        assert_eq!(u.as_u32(), vec![7, 8, 9]);
        let s = Tensor::scalar_i32(-5);
        assert_eq!(s.rank(), 0);
        assert_eq!(s.as_i32(), vec![-5]);
    }

    #[test]
    fn tensor_wire_roundtrip() {
        for t in [
            Tensor::from_f32(vec![2, 3], &[0.5; 6]),
            Tensor::from_u8(vec![4], vec![1, 2, 3, 4]),
            Tensor::scalar_u32(9),
        ] {
            let back = Tensor::from_bytes(&t.to_bytes()).unwrap();
            assert_eq!(t, back);
        }
    }

    #[test]
    fn tensor_decode_validates_length() {
        let t = Tensor::from_f32(vec![2], &[1.0, 2.0]);
        let mut bytes = t.to_bytes();
        // Corrupt the declared shape (first dim 2 -> 3).
        bytes[5] = 3;
        assert!(Tensor::from_bytes(&bytes).is_err());
    }

    #[test]
    fn stack_same_shape() {
        let a = Tensor::from_f32(vec![2], &[1.0, 2.0]);
        let b = Tensor::from_f32(vec![2], &[3.0, 4.0]);
        let s = Tensor::stack(&[a, b]).unwrap();
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.as_f32(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn stack_rejects_mismatch() {
        let a = Tensor::from_f32(vec![2], &[1.0, 2.0]);
        let b = Tensor::from_f32(vec![3], &[3.0, 4.0, 5.0]);
        assert!(Tensor::stack(&[a, b]).is_err());
    }

    #[test]
    fn stack_padded_pads_to_longest() {
        let a = Tensor::from_u32(vec![2], &[1, 2]);
        let b = Tensor::from_u32(vec![4], &[3, 4, 5, 6]);
        let s = Tensor::stack_padded(&[a, b], &0u32.to_le_bytes()).unwrap();
        assert_eq!(s.shape, vec![2, 4]);
        assert_eq!(s.as_u32(), vec![1, 2, 0, 0, 3, 4, 5, 6]);
    }

    #[test]
    fn element_roundtrip_with_ids() {
        let e = Element::with_ids(
            vec![Tensor::from_f32(vec![1], &[1.0]), Tensor::scalar_u32(3)],
            vec![42],
        );
        let back = Element::from_bytes(&e.to_bytes()).unwrap();
        assert_eq!(e, back);
        assert_eq!(back.batch_dim(), Some(1));
    }

    #[test]
    fn dtype_names_roundtrip() {
        for d in [DType::U8, DType::U32, DType::I32, DType::I64, DType::F32] {
            assert_eq!(DType::from_name(d.name()), Some(d));
        }
        assert_eq!(DType::from_name("f64"), None);
    }
}
